//! Real sockets between real processes: [`TcpTransport`].
//!
//! The in-process transports ([`super::Loopback`], [`super::SimNet`])
//! move frames between queues that live in one address space. This one
//! moves the *same* frames over TCP so `fedskel serve` and `fedskel
//! client` can be separate processes on separate machines — the
//! deployment FedSkel actually targets. The payload codecs
//! ([`super::wire`] for the data plane, [`super::proto`] for the control
//! plane) are byte-identical either way; this module only adds the outer
//! length framing and connection management.
//!
//! ## Outer frame (little-endian)
//!
//! | bytes | field |
//! |-------|-------|
//! | 0..4  | magic `b"FSKT"` |
//! | 4..8  | `from` peer code (u32; server = `0xFFFF_FFFF`, client *i* = *i*) |
//! | 8..12 | `to` peer code |
//! | 12..16| payload length (u32) |
//! | 16..  | payload (a wire or proto frame) |
//!
//! A zero-length payload is a **link hello**: it identifies the remote
//! peer for this connection (registering the write side) and is never
//! delivered as a message.
//!
//! ## Connection model
//!
//! * [`TcpTransport::listen`] — server mode: an accept thread spawns one
//!   reader thread per connection; the first frame's `from` names the
//!   peer and registers the connection as the write path to it.
//! * [`TcpTransport::connect`] — client mode: one connection to the
//!   server, announced with a hello. [`TcpTransport::connect_with_backoff`]
//!   retries with doubling sleeps (100 ms → 3.2 s cap) so clients ride
//!   out a server restart; *process-level* reconnect policy (a fresh
//!   transport per attempt) lives in `fedskel client`'s outer loop.
//!
//! ## Backpressure
//!
//! Each destination peer's inbox is bounded (default 64 MiB,
//! [`TcpTransport::with_inbox_cap`]). A reader thread whose destination
//! inbox is full parks on a condvar instead of buffering without bound;
//! the kernel's TCP window then fills and the remote `send` blocks — flow
//! control end to end with no unbounded queue anywhere. A single frame
//! larger than the cap is still accepted (into an empty inbox), so the
//! cap can never deadlock a sender.
//!
//! `recv` is the trait's typed would-block ([`super::Transport::recv`]);
//! [`TcpTransport::recv_wait`] adds a condvar-timed blocking variant for
//! event loops. Join/leave transitions surface as [`LinkEvent`]s via
//! [`TcpTransport::drain_link_events`] — `fedskel serve` turns them into
//! `client_join` / `client_leave` trace events.

use std::collections::{BTreeMap, VecDeque};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::{Envelope, Peer, Receipt, Transport};

/// Outer-frame magic (distinct from wire `FSKL` and proto `FSKP`).
pub const MAGIC: [u8; 4] = *b"FSKT";
/// Outer-frame header bytes before the payload.
pub const HEADER_LEN: usize = 16;
/// Refuse frames larger than this (a corrupt length must not OOM us).
pub const MAX_FRAME: usize = 256 << 20;
/// Default per-peer inbox budget in bytes.
pub const DEFAULT_INBOX_CAP: usize = 64 << 20;

/// A connection came up or went down.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkEvent {
    Joined(Peer),
    Left(Peer),
}

fn peer_code(p: Peer) -> u32 {
    match p {
        Peer::Server => u32::MAX,
        Peer::Client(i) => i as u32,
    }
}

fn code_peer(c: u32) -> Peer {
    if c == u32::MAX {
        Peer::Server
    } else {
        Peer::Client(c as usize)
    }
}

#[derive(Default)]
struct Inbox {
    q: BTreeMap<Peer, VecDeque<Envelope>>,
    bytes: BTreeMap<Peer, usize>,
}

impl Inbox {
    fn pop(&mut self, to: Peer) -> Option<Envelope> {
        let env = self.q.get_mut(&to)?.pop_front()?;
        if let Some(b) = self.bytes.get_mut(&to) {
            *b = b.saturating_sub(env.frame.len());
        }
        Some(env)
    }
}

struct Shared {
    inbox: Mutex<Inbox>,
    cv: Condvar,
    writers: Mutex<BTreeMap<Peer, TcpStream>>,
    links: Mutex<Vec<LinkEvent>>,
    closed: AtomicBool,
    cap: AtomicUsize,
}

impl Shared {
    fn new() -> Arc<Shared> {
        Arc::new(Shared {
            inbox: Mutex::new(Inbox::default()),
            cv: Condvar::new(),
            writers: Mutex::new(BTreeMap::new()),
            links: Mutex::new(Vec::new()),
            closed: AtomicBool::new(false),
            cap: AtomicUsize::new(DEFAULT_INBOX_CAP),
        })
    }

    fn push_link(&self, ev: LinkEvent) {
        self.links.lock().expect("links lock").push(ev);
        self.cv.notify_all();
    }
}

fn read_frame(conn: &mut TcpStream) -> std::io::Result<(Peer, Peer, Vec<u8>)> {
    use std::io::{Error, ErrorKind};
    let mut head = [0u8; HEADER_LEN];
    conn.read_exact(&mut head)?;
    if head[0..4] != MAGIC {
        return Err(Error::new(ErrorKind::InvalidData, "bad tcp frame magic"));
    }
    let from = code_peer(u32::from_le_bytes(head[4..8].try_into().unwrap()));
    let to = code_peer(u32::from_le_bytes(head[8..12].try_into().unwrap()));
    let len = u32::from_le_bytes(head[12..16].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        return Err(Error::new(ErrorKind::InvalidData, "tcp frame exceeds MAX_FRAME"));
    }
    let mut payload = vec![0u8; len];
    conn.read_exact(&mut payload)?;
    Ok((from, to, payload))
}

/// One connection's read loop. `peer` is pre-set for client-side
/// connections (the remote end is the server); server-side connections
/// learn it from the first frame's `from`.
fn reader_loop(shared: Arc<Shared>, mut conn: TcpStream, mut peer: Option<Peer>) {
    let mut write_side = conn.try_clone().ok();
    loop {
        if shared.closed.load(Ordering::SeqCst) {
            break;
        }
        let Ok((from, to, payload)) = read_frame(&mut conn) else { break };
        if peer.is_none() {
            peer = Some(from);
            if let Some(s) = write_side.take() {
                shared.writers.lock().expect("writers lock").insert(from, s);
            }
            shared.push_link(LinkEvent::Joined(from));
        }
        if payload.is_empty() {
            continue; // link hello — identification only
        }
        let mut inbox = shared.inbox.lock().expect("inbox lock");
        loop {
            let used = inbox.bytes.get(&to).copied().unwrap_or(0);
            let cap = shared.cap.load(Ordering::SeqCst);
            if used == 0 || used + payload.len() <= cap || shared.closed.load(Ordering::SeqCst) {
                break;
            }
            // inbox full: park. The socket stops being read, the TCP
            // window fills, the remote sender blocks — end-to-end flow
            // control with no unbounded buffer.
            inbox = shared.cv.wait(inbox).expect("inbox lock");
        }
        if shared.closed.load(Ordering::SeqCst) {
            break;
        }
        *inbox.bytes.entry(to).or_insert(0) += payload.len();
        inbox.q.entry(to).or_default().push_back(Envelope { from, to, frame: payload });
        drop(inbox);
        shared.cv.notify_all();
    }
    if let Some(p) = peer {
        shared.writers.lock().expect("writers lock").remove(&p);
        shared.push_link(LinkEvent::Left(p));
    }
    shared.cv.notify_all();
}

/// The real-socket [`Transport`]. See the module docs for the frame
/// layout and connection model.
pub struct TcpTransport {
    shared: Arc<Shared>,
    /// Bound address in listen mode (`None` for client connections).
    local_addr: Option<SocketAddr>,
    /// Total payload bytes ever sent.
    pub bytes_sent: u64,
}

impl TcpTransport {
    /// Server mode: bind `addr` (use port 0 to let the OS pick — read it
    /// back with [`TcpTransport::local_addr`]) and accept connections.
    pub fn listen(addr: &str) -> Result<TcpTransport> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local_addr = listener.local_addr()?;
        let shared = Shared::new();
        let sh = Arc::clone(&shared);
        std::thread::Builder::new()
            .name("tcp-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if sh.closed.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let _ = stream.set_nodelay(true);
                    let sh2 = Arc::clone(&sh);
                    let _ = std::thread::Builder::new()
                        .name("tcp-reader".into())
                        .spawn(move || reader_loop(sh2, stream, None));
                }
            })
            .context("spawning tcp-accept")?;
        Ok(TcpTransport { shared, local_addr: Some(local_addr), bytes_sent: 0 })
    }

    /// Client mode: one connection to the server at `addr`, announced
    /// with a hello naming this process's peer id `me`.
    pub fn connect(addr: &str, me: Peer) -> Result<TcpTransport> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        let _ = stream.set_nodelay(true);
        let shared = Shared::new();
        shared
            .writers
            .lock()
            .expect("writers lock")
            .insert(Peer::Server, stream.try_clone().context("cloning stream")?);
        let sh = Arc::clone(&shared);
        std::thread::Builder::new()
            .name("tcp-reader".into())
            .spawn(move || reader_loop(sh, stream, Some(Peer::Server)))
            .context("spawning tcp-reader")?;
        let mut t = TcpTransport { shared, local_addr: None, bytes_sent: 0 };
        // hello: zero-length payload, identifies `me` to the server
        t.write_raw(Envelope { from: me, to: Peer::Server, frame: Vec::new() })?;
        Ok(t)
    }

    /// [`TcpTransport::connect`] with doubling retry sleeps (100 ms →
    /// 3.2 s cap) until `timeout` elapses — rides out a server restart.
    pub fn connect_with_backoff(addr: &str, me: Peer, timeout: Duration) -> Result<TcpTransport> {
        let start = Instant::now();
        let mut delay = Duration::from_millis(100);
        loop {
            match TcpTransport::connect(addr, me) {
                Ok(t) => return Ok(t),
                Err(e) if start.elapsed() >= timeout => {
                    return Err(e.context(format!("giving up on {addr} after {timeout:?}")));
                }
                Err(_) => {
                    std::thread::sleep(delay.min(timeout.saturating_sub(start.elapsed())));
                    delay = (delay * 2).min(Duration::from_millis(3200));
                }
            }
        }
    }

    /// The bound address in listen mode.
    pub fn local_addr(&self) -> Option<SocketAddr> {
        self.local_addr
    }

    /// Cap each destination peer's inbox at `bytes` (see module docs).
    pub fn with_inbox_cap(self, bytes: usize) -> TcpTransport {
        self.shared.cap.store(bytes.max(1), Ordering::SeqCst);
        self
    }

    /// Peers with a live write path right now.
    pub fn connected(&self) -> Vec<Peer> {
        self.shared.writers.lock().expect("writers lock").keys().copied().collect()
    }

    /// Take the join/leave transitions observed since the last drain.
    pub fn drain_link_events(&self) -> Vec<LinkEvent> {
        std::mem::take(&mut *self.shared.links.lock().expect("links lock"))
    }

    /// Blocking [`Transport::recv`]: wait up to `timeout` for a message
    /// addressed to `to`. `Ok(None)` on timeout.
    pub fn recv_wait(&self, to: Peer, timeout: Duration) -> Result<Option<Envelope>> {
        let deadline = Instant::now() + timeout;
        let mut inbox = self.shared.inbox.lock().expect("inbox lock");
        loop {
            if let Some(env) = inbox.pop(to) {
                drop(inbox);
                self.shared.cv.notify_all(); // a parked reader may now fit
                return Ok(Some(env));
            }
            let now = Instant::now();
            if now >= deadline || self.shared.closed.load(Ordering::SeqCst) {
                return Ok(None);
            }
            let (guard, _) = self
                .shared
                .cv
                .wait_timeout(inbox, deadline - now)
                .expect("inbox lock");
            inbox = guard;
        }
    }

    fn write_raw(&mut self, msg: Envelope) -> Result<usize> {
        let bytes = msg.frame.len();
        let writers = self.shared.writers.lock().expect("writers lock");
        let Some(stream) = writers.get(&msg.to) else {
            bail!("tcp: no connection to {:?}", msg.to);
        };
        let mut out = Vec::with_capacity(HEADER_LEN + bytes);
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&peer_code(msg.from).to_le_bytes());
        out.extend_from_slice(&peer_code(msg.to).to_le_bytes());
        out.extend_from_slice(&(bytes as u32).to_le_bytes());
        out.extend_from_slice(&msg.frame);
        let mut w: &TcpStream = stream;
        w.write_all(&out).with_context(|| format!("tcp send to {:?}", msg.to))?;
        Ok(bytes)
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, msg: Envelope) -> Result<Receipt> {
        let bytes = self.write_raw(msg)?;
        self.bytes_sent += bytes as u64;
        // no link simulation on a real link: the wall clock is real here
        Ok(Receipt { bytes, sim_secs: 0.0 })
    }

    fn recv(&mut self, to: Peer) -> Result<Option<Envelope>> {
        let mut inbox = self.shared.inbox.lock().expect("inbox lock");
        let env = inbox.pop(to);
        drop(inbox);
        if env.is_some() {
            self.shared.cv.notify_all();
        }
        Ok(env)
    }

    fn pending(&self, to: Peer) -> usize {
        let inbox = self.shared.inbox.lock().expect("inbox lock");
        inbox.q.get(&to).map(|q| q.len()).unwrap_or(0)
    }

    fn name(&self) -> &'static str {
        "tcp"
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.shared.closed.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        // shut every connection down so reader threads unblock and exit
        let writers = self.shared.writers.lock().expect("writers lock");
        for stream in writers.values() {
            let _ = stream.shutdown(std::net::Shutdown::Both);
        }
        drop(writers);
        // wake the accept thread with a throwaway connection
        if let Some(addr) = self.local_addr {
            let _ = TcpStream::connect_timeout(&addr, Duration::from_millis(200));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const T: Duration = Duration::from_secs(5);

    fn pair() -> (TcpTransport, TcpTransport, String) {
        let server = TcpTransport::listen("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let client = TcpTransport::connect(&addr, Peer::Client(3)).unwrap();
        (server, client, addr)
    }

    fn env(from: Peer, to: Peer, frame: Vec<u8>) -> Envelope {
        Envelope { from, to, frame }
    }

    #[test]
    fn hello_registers_and_frames_flow_both_ways() {
        let (mut server, mut client, _) = pair();
        // client → server
        client.send(env(Peer::Client(3), Peer::Server, vec![1, 2, 3])).unwrap();
        let up = server.recv_wait(Peer::Server, T).unwrap().unwrap();
        assert_eq!(up.from, Peer::Client(3));
        assert_eq!(up.frame, vec![1, 2, 3]);
        // the hello registered a write path back
        assert!(server.connected().contains(&Peer::Client(3)));
        assert!(server
            .drain_link_events()
            .contains(&LinkEvent::Joined(Peer::Client(3))));
        // server → client
        server.send(env(Peer::Server, Peer::Client(3), vec![9; 40])).unwrap();
        let down = client.recv_wait(Peer::Client(3), T).unwrap().unwrap();
        assert_eq!(down.frame.len(), 40);
        assert_eq!(server.bytes_sent, 40);
    }

    #[test]
    fn empty_queue_is_a_typed_would_block() {
        let (mut server, _client, _) = pair();
        assert!(server.recv(Peer::Server).unwrap().is_none());
        assert!(server.recv_wait(Peer::Server, Duration::from_millis(20)).unwrap().is_none());
    }

    #[test]
    fn send_to_unknown_peer_is_an_error() {
        let mut server = TcpTransport::listen("127.0.0.1:0").unwrap();
        let e = server.send(env(Peer::Server, Peer::Client(0), vec![1])).unwrap_err();
        assert!(e.to_string().contains("no connection"), "{e:#}");
    }

    #[test]
    fn fifo_per_connection_and_pending_counts() {
        let (server, mut client, _) = pair();
        for i in 0..5u8 {
            client.send(env(Peer::Client(3), Peer::Server, vec![i; 4])).unwrap();
        }
        // wait for all 5 to land, then check order
        let deadline = Instant::now() + T;
        while server.pending(Peer::Server) < 5 {
            assert!(Instant::now() < deadline, "frames never arrived");
            std::thread::sleep(Duration::from_millis(5));
        }
        let mut server = server;
        for i in 0..5u8 {
            let e = server.recv(Peer::Server).unwrap().unwrap();
            assert_eq!(e.frame[0], i);
        }
    }

    #[test]
    fn disconnect_surfaces_as_a_leave_event() {
        let (server, client, _) = pair();
        // make sure the join landed first
        let deadline = Instant::now() + T;
        while !server.connected().contains(&Peer::Client(3)) {
            assert!(Instant::now() < deadline);
            std::thread::sleep(Duration::from_millis(5));
        }
        drop(client);
        let deadline = Instant::now() + T;
        loop {
            if server.drain_link_events().contains(&LinkEvent::Left(Peer::Client(3))) {
                break;
            }
            assert!(Instant::now() < deadline, "leave never observed");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(!server.connected().contains(&Peer::Client(3)));
    }

    #[test]
    fn inbox_cap_defers_delivery_without_losing_frames() {
        let server = TcpTransport::listen("127.0.0.1:0").unwrap().with_inbox_cap(10);
        let addr = server.local_addr().unwrap().to_string();
        let mut client = TcpTransport::connect(&addr, Peer::Client(0)).unwrap();
        // 4 frames of 8 bytes: the cap (10) holds only one at a time, the
        // reader parks; popping releases the next. Nothing is dropped.
        for i in 0..4u8 {
            client.send(env(Peer::Client(0), Peer::Server, vec![i; 8])).unwrap();
        }
        for i in 0..4u8 {
            let e = server.recv_wait(Peer::Server, T).unwrap().unwrap();
            assert_eq!(e.frame, vec![i; 8], "in order, none lost");
        }
    }

    #[test]
    fn oversize_frame_is_refused_and_drops_the_connection() {
        let (server, _client, addr) = pair();
        // handcraft a header claiming a > MAX_FRAME payload
        let mut raw = TcpStream::connect(&addr).unwrap();
        let mut head = Vec::new();
        head.extend_from_slice(&MAGIC);
        head.extend_from_slice(&peer_code(Peer::Client(9)).to_le_bytes());
        head.extend_from_slice(&peer_code(Peer::Server).to_le_bytes());
        head.extend_from_slice(&(u32::MAX).to_le_bytes());
        raw.write_all(&head).unwrap();
        // the server must refuse (connection dies) rather than allocate
        let mut buf = [0u8; 1];
        raw.set_read_timeout(Some(T)).unwrap();
        let n = raw.read(&mut buf).unwrap_or(0);
        assert_eq!(n, 0, "server should close the connection");
        assert_eq!(server.pending(Peer::Server), 0);
    }

    #[test]
    fn connect_with_backoff_times_out_cleanly() {
        // a port nobody listens on (bind then drop to reserve-and-free)
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let e = TcpTransport::connect_with_backoff(
            &addr,
            Peer::Client(0),
            Duration::from_millis(300),
        )
        .unwrap_err();
        assert!(e.to_string().contains("giving up"), "{e:#}");
    }
}
