//! Integration: the native CPU backend's gradients are *correct*
//! (finite-difference check), its skeleton-sliced backward is *exact* on
//! the selected channels (bitwise parity with the full backward), and the
//! coordinator runs end-to-end on it — real compute substituted for
//! `MockBackend`.

use fedskel::config::{Method, RunConfig};
use fedskel::coordinator::Coordinator;
use fedskel::kernels::{Conv2d, Parallelism};
use fedskel::model::{init_params, ParamSpec, Params, PrunableSpec};
use fedskel::runtime::native::{prefix_skeleton, Layer, NativeBackend, NativeModel};
use fedskel::runtime::step::Backend;
use fedskel::util::Rng;

fn batch(model: &NativeModel, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let spec = &model.spec;
    let mut rng = Rng::new(seed);
    let numel: usize = spec.input_shape.iter().product();
    let x = (0..spec.train_batch * numel).map(|_| rng.normal() * 0.5).collect();
    let y = (0..spec.train_batch).map(|i| (i % spec.num_classes) as i32).collect();
    (x, y)
}

// ---------------------------------------------------------------- gradcheck

/// Pool-free conv+dense net whose ReLUs are pushed deep into their linear
/// region (lifted biases, positive inputs), so the loss is locally smooth
/// and central differences are trustworthy.
fn smooth_fd_model() -> NativeModel {
    let c = Conv2d { in_h: 6, in_w: 6, cin: 1, cout: 2, kh: 3, kw: 3 }; // →4×4×2 = 32
    let params = vec![
        ParamSpec { name: "conv.w".into(), shape: vec![3, 3, 1, 2], init: "he".into() },
        ParamSpec { name: "conv.b".into(), shape: vec![2], init: "zeros".into() },
        ParamSpec { name: "head.w".into(), shape: vec![32, 3], init: "glorot".into() },
        ParamSpec { name: "head.b".into(), shape: vec![3], init: "zeros".into() },
    ];
    let prunable =
        vec![PrunableSpec { name: "conv".into(), channels: 2, weight_param: 0, bias_param: 1 }];
    let layers = vec![
        Layer::Conv { conv: c, w: 0, b: 1, prunable: Some(0), pool: false },
        Layer::Dense { in_dim: 32, out_dim: 3, w: 2, b: 3, prunable: None, relu: false },
    ];
    NativeModel::custom("fd_smooth", vec![6, 6, 1], 3, 2, 2, params, prunable, &[100], layers)
}

#[test]
fn finite_difference_gradient_check() {
    let model = smooth_fd_model();
    let mut params = init_params(&model.spec, 17);
    // tame the weights and lift the conv bias so every pre-activation
    // sits deep inside the ReLU's linear region: the loss is then C²
    // throughout the FD stencil and central differences are trustworthy.
    for t in params.iter_mut() {
        t.scale(0.25);
    }
    params[1].data_mut().fill(1.0);
    let mut rng = Rng::new(23);
    let x: Vec<f32> = (0..2 * 36).map(|_| 0.1 + rng.normal().abs() * 0.3).collect();
    let y = vec![0i32, 2];
    let skel = vec![vec![0i32, 1]];

    let trace = model.forward(&params, &x, 2).unwrap();
    // smoothness precondition: no conv activation anywhere near the kink
    // at the perturbation scale (eps · |input| ≲ 3e-3)
    let margin = trace.layer_output(0).iter().cloned().fold(f32::INFINITY, f32::min);
    assert!(margin > 0.05, "ReLU margin {margin} too small for a clean FD check");
    let (_l0, dlog) = model.loss_grad(&trace, &y).unwrap();
    let (grads, _imp) = model.backward(&x, &params, &trace, &dlog, &skel).unwrap();

    let loss_at = |p: &Params| -> f64 {
        let t = model.forward(p, &x, 2).unwrap();
        model.loss_grad(&t, &y).unwrap().0 as f64
    };

    let eps = 1e-2f32;
    let mut max_rel = 0.0f32;
    let mut worst = (0usize, 0usize);
    for pi in 0..params.len() {
        for i in 0..params[pi].len() {
            let mut pp = params.clone();
            pp[pi].data_mut()[i] += eps;
            let lp = loss_at(&pp);
            pp[pi].data_mut()[i] -= 2.0 * eps;
            let lm = loss_at(&pp);
            let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
            let a = grads[pi][i];
            let rel = (a - fd).abs() / (a.abs() + fd.abs() + 1.0);
            if rel > max_rel {
                max_rel = rel;
                worst = (pi, i);
            }
            if fd.abs() > 0.1 {
                assert!(
                    (a - fd).abs() / fd.abs() < 1e-2,
                    "param {pi}[{i}]: analytic {a} vs fd {fd}"
                );
            }
        }
    }
    assert!(
        max_rel < 1e-3,
        "max normalized gradient error {max_rel} at param {}[{}]",
        worst.0,
        worst.1
    );
}

// ------------------------------------------------------------------ parity

#[test]
fn sliced_backward_matches_full_on_selected_channels() {
    // one prunable layer (tiny): the sliced backward must be *bitwise*
    // the full backward restricted to the skeleton channels.
    let model = NativeModel::tiny();
    let params = init_params(&model.spec, 5);
    let (x, y) = batch(&model, 6);
    let trace = model.forward(&params, &x, model.spec.train_batch).unwrap();
    let (_l, dlog) = model.loss_grad(&trace, &y).unwrap();
    let full = prefix_skeleton(&[4]);
    let (g_full, imp_full) = model.backward(&x, &params, &trace, &dlog, &full).unwrap();
    let idx = vec![1i32, 3];
    let (g_s, imp_s) = model.backward(&x, &params, &trace, &dlog, &[idx.clone()]).unwrap();

    // conv1 weight [5,5,1,4]: columns 1,3 identical, columns 0,2 zero
    let channels = 4;
    for (i, (&s, &f)) in g_s[0].iter().zip(&g_full[0]).enumerate() {
        let c = i % channels;
        if c == 1 || c == 3 {
            assert!(s == f, "conv w grad differs at {i}: {s} vs {f}");
        } else {
            assert_eq!(s, 0.0, "non-skeleton conv w grad nonzero at {i}");
        }
    }
    for c in 0..channels {
        if c == 1 || c == 3 {
            assert!(g_s[1][c] == g_full[1][c]);
            assert!(imp_s[0][c] == imp_full[0][c]);
        } else {
            assert_eq!(g_s[1][c], 0.0);
            assert_eq!(imp_s[0][c], 0.0);
        }
    }
    // the head sits above the prunable layer: its gradients are exact
    assert_eq!(g_s[2], g_full[2]);
    assert_eq!(g_s[3], g_full[3]);
}

#[test]
fn lenet_deepest_prunable_layer_is_exact_and_rest_untouched() {
    let model = NativeModel::lenet();
    let params = init_params(&model.spec, 8);
    let (x, y) = batch(&model, 9);
    let trace = model.forward(&params, &x, model.spec.train_batch).unwrap();
    let (_l, dlog) = model.loss_grad(&trace, &y).unwrap();
    let full = prefix_skeleton(&model.spec.skel_sizes(100));
    let r25 = prefix_skeleton(&model.spec.skel_sizes(25)); // k = [2,4,30,21]
    let (g_full, _) = model.backward(&x, &params, &trace, &dlog, &full).unwrap();
    let (g_s, _) = model.backward(&x, &params, &trace, &dlog, &r25).unwrap();

    // fc2 (deepest prunable, param 6, 84 channels) receives the exact
    // upstream gradient from the non-prunable head, so its skeleton
    // channels match the full backward bitwise.
    let c2 = 84;
    for (i, (&s, &f)) in g_s[6].iter().zip(&g_full[6]).enumerate() {
        let c = i % c2;
        if c < 21 {
            assert!(s == f, "fc2 grad differs at {i}");
        } else {
            assert_eq!(s, 0.0);
        }
    }
    // head grads exact in both runs
    assert_eq!(g_s[8], g_full[8]);

    // and a sliced train_step leaves every non-skeleton parameter of
    // every prunable layer bit-identical
    let mut backend = NativeBackend::lenet();
    let out = backend.train_step(25, &params, &params, &x, &y, &r25, 0.05, 0.0).unwrap();
    for (li, p) in model.spec.prunable.iter().enumerate() {
        let k = r25[li].len();
        for &pi in &[p.weight_param, p.bias_param] {
            let (new, old) = (out.params[pi].data(), params[pi].data());
            for (i, (&n, &o)) in new.iter().zip(old).enumerate() {
                let c = i % p.channels;
                if c >= k {
                    assert!(n == o, "param {pi} channel {c} moved (layer {li})");
                }
            }
        }
    }
}

#[test]
fn parallel_backward_bitwise_matches_serial_at_every_thread_count() {
    // The determinism contract of kernels/parallel.rs, end to end on the
    // LeNet model: forward trace, loss gradient, sliced backward, and
    // Eq. 2 importances are bitwise identical at 1, 2, and 7 threads
    // (7 forces ragged tail shards on every kernel).
    let base = NativeModel::lenet();
    let params = init_params(&base.spec, 11);
    let (x, y) = batch(&base, 12);
    let trace = base.forward(&params, &x, base.spec.train_batch).unwrap();
    let (_l, dlog) = base.loss_grad(&trace, &y).unwrap();
    let skel = prefix_skeleton(&base.spec.skel_sizes(25));
    let (g_serial, imp_serial) = base.backward(&x, &params, &trace, &dlog, &skel).unwrap();
    for threads in [2usize, 7] {
        let model = base.clone().with_parallelism(Parallelism::new(threads));
        let trace_t = model.forward(&params, &x, model.spec.train_batch).unwrap();
        let (_lt, dlog_t) = model.loss_grad(&trace_t, &y).unwrap();
        assert_eq!(dlog, dlog_t, "{threads}-thread forward diverged");
        let (g_t, imp_t) = model.backward(&x, &params, &trace_t, &dlog_t, &skel).unwrap();
        assert_eq!(g_serial, g_t, "{threads}-thread gradients diverged");
        assert_eq!(imp_serial, imp_t, "{threads}-thread importances diverged");
    }
}

// ----------------------------------------------------------- coordinator

fn native_cfg(rounds: usize) -> RunConfig {
    RunConfig {
        method: Method::FedSkel,
        model: "tiny_native".into(),
        num_clients: 4,
        shards_per_client: 2,
        dataset_size: 240,
        new_test_size: 32,
        rounds,
        local_steps: 2,
        updateskel_per_setskel: 3,
        eval_every: 0,
        lr: 0.08,
        ..RunConfig::default()
    }
}

#[test]
fn coordinator_e2e_round_on_native_backend() {
    let mut c = Coordinator::new(native_cfg(8), NativeBackend::tiny()).unwrap();
    c.run().unwrap();
    assert_eq!(c.log.rounds.len(), 8);
    assert!(c.log.rounds.iter().all(|r| r.mean_loss.is_finite()));
    // real SGD on the synthetic shards must make progress
    let first = c.log.rounds[0].mean_loss;
    let best = c.log.rounds.iter().map(|r| r.mean_loss).fold(f64::INFINITY, f64::min);
    assert!(best < first, "loss never improved: first {first}, best {best}");
    // SetSkel round selected real skeletons sized for each client's bucket
    for cl in &c.clients {
        let k = c.backend.spec().train_artifact(cl.bucket).unwrap().k[0];
        assert_eq!(cl.skeleton[0].len(), k, "client {}", cl.id);
    }
    let acc = c.log.last_local_acc().unwrap();
    assert!((0.0..=1.0).contains(&acc));
    assert!(c.ledger.total_wire_bytes() > 0);
}

#[test]
fn coordinator_round_metrics_identical_across_thread_counts() {
    // Straggler *timing* is emergent, but round *semantics* must not
    // depend on the thread budget: same losses, same wire bytes, same
    // final global model at --threads 1 and --threads 3.
    let run = |threads: usize| {
        let mut cfg = native_cfg(4);
        cfg.threads = threads;
        let mut c = Coordinator::new(cfg, NativeBackend::tiny()).unwrap();
        c.run().unwrap();
        c
    };
    let serial = run(1);
    let threaded = run(3);
    assert_eq!(serial.global, threaded.global);
    assert_eq!(serial.log.rounds.len(), threaded.log.rounds.len());
    for (a, b) in serial.log.rounds.iter().zip(&threaded.log.rounds) {
        assert_eq!(a.mean_loss, b.mean_loss, "round {}", a.round);
        assert_eq!(a.comm_wire_bytes, b.comm_wire_bytes, "round {}", a.round);
        assert_eq!(a.comm_params, b.comm_params, "round {}", a.round);
    }
    assert_eq!(
        fedskel::model::params_digest(&serial.global),
        fedskel::model::params_digest(&threaded.global)
    );
}

#[test]
fn native_pool_and_inline_agree_bitwise() {
    let mut inline = Coordinator::new(native_cfg(4), NativeBackend::tiny()).unwrap();
    inline.run().unwrap();
    let workers: Vec<NativeBackend> = (0..2).map(|_| NativeBackend::tiny()).collect();
    let mut pooled =
        Coordinator::with_pool(native_cfg(4), NativeBackend::tiny(), workers).unwrap();
    pooled.run().unwrap();
    assert_eq!(inline.global, pooled.global);
    for (a, b) in inline.log.rounds.iter().zip(&pooled.log.rounds) {
        assert_eq!(a.mean_loss, b.mean_loss);
    }
}
