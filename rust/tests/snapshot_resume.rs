//! Bitwise resume parity: `run(2N)` must equal `run(N) → snapshot →
//! fresh-process restore → run(N)` — same param digest, same tensors,
//! same comm ledger, same round log (modulo the wall-clock column).
//!
//! The matrix covers every scheduling policy × compressor × thread count
//! on the tiny native model, plus a LeNet spot-check on the heaviest
//! cell. Simulated batch seconds are pinned so the virtual clock is a
//! pure function of the config — exactly what a real cross-process
//! resume (CI's smoke test) requires.

use std::collections::BTreeMap;
use std::path::PathBuf;

use fedskel::compress::CompressKind;
use fedskel::config::{Method, RunConfig};
use fedskel::coordinator::Coordinator;
use fedskel::kernels::Parallelism;
use fedskel::model::params_digest;
use fedskel::runtime::native::NativeBackend;
use fedskel::runtime::step::Backend;
use fedskel::sched::SchedKind;
use fedskel::snapshot::SnapshotError;

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("fedskel_resume_{}", std::process::id()))
        .join(format!("{tag}.fsnap"))
}

/// Build the backend for `cfg` with pinned per-bucket batch seconds
/// (`bucket% × 0.08s`), so two independently constructed backends — the
/// uninterrupted run and the resumed one — agree on the sim clock bit
/// for bit.
fn backend(cfg: &RunConfig) -> NativeBackend {
    let b = if cfg.model == "lenet_native" {
        NativeBackend::lenet()
    } else {
        NativeBackend::tiny()
    };
    let b = b.with_parallelism(Parallelism::new(cfg.threads));
    let secs: BTreeMap<usize, f64> = b
        .spec()
        .train_buckets()
        .into_iter()
        .map(|bk| (bk, bk as f64 / 100.0 * 0.08))
        .collect();
    b.with_fixed_batch_secs(secs)
}

fn base_cfg(model: &str, sched: SchedKind, compress: CompressKind, threads: usize) -> RunConfig {
    let mut cfg = RunConfig {
        method: Method::FedSkel,
        model: model.into(),
        num_clients: 4,
        shards_per_client: 2,
        dataset_size: 240,
        new_test_size: 32,
        rounds: 6,
        local_steps: 1,
        updateskel_per_setskel: 2,
        eval_every: 0,
        seed: 7,
        threads,
        compress,
        sched,
        ..RunConfig::default()
    };
    match sched {
        SchedKind::Sync => {}
        // tight enough that slow devices actually get dropped
        SchedKind::DeadlineDrop => cfg.deadline_secs = 0.5,
        // K=3 of 4: every round leaves a straggler in flight
        SchedKind::AsyncBuffer => {
            cfg.buffer_k = 3;
            cfg.staleness_alpha = 0.5;
        }
    }
    match compress {
        CompressKind::Int8 => cfg.error_feedback = true,
        CompressKind::TopK => {
            cfg.topk_ratio = 0.25;
            cfg.error_feedback = true;
        }
        _ => {}
    }
    cfg
}

/// Drop the trailing `wall_secs` column — the only nondeterministic CSV
/// cell (`client_secs` joins pairs with `;`, so the last comma is safe).
fn strip_wall(csv: &str) -> String {
    csv.lines()
        .map(|l| l.rsplit_once(',').map(|(head, _)| head).unwrap_or(l))
        .collect::<Vec<_>>()
        .join("\n")
}

/// run(2N) vs run(N) → checkpoint → restore into a fresh coordinator →
/// run(N). The restored side shares nothing with the first half except
/// the snapshot bytes.
fn assert_resume_parity(cfg: RunConfig, tag: &str) {
    let half = cfg.rounds / 2;

    let mut full = Coordinator::new(cfg.clone(), backend(&cfg)).unwrap();
    full.run().unwrap();

    let mut first = Coordinator::new(cfg.clone(), backend(&cfg)).unwrap();
    for _ in 0..half {
        first.step_round().unwrap();
    }
    let path = tmp(tag);
    first.checkpoint(&path).unwrap();
    drop(first);

    let mut resumed = Coordinator::restore(cfg.clone(), backend(&cfg), &path).unwrap();
    assert_eq!(resumed.round_idx(), half, "{tag}: restored round index");
    assert_eq!(resumed.registry.counter("run/resumes"), 1, "{tag}");
    resumed.run().unwrap();

    assert_eq!(
        params_digest(&full.global),
        params_digest(&resumed.global),
        "{tag}: param digest diverged"
    );
    assert_eq!(full.global, resumed.global, "{tag}: global tensors diverged");
    assert_eq!(full.ledger, resumed.ledger, "{tag}: comm ledger diverged");
    assert_eq!(
        strip_wall(&full.log.to_csv()),
        strip_wall(&resumed.log.to_csv()),
        "{tag}: round log diverged"
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn resume_parity_matrix_tiny_native() {
    for sched in [SchedKind::Sync, SchedKind::DeadlineDrop, SchedKind::AsyncBuffer] {
        for compress in [CompressKind::Identity, CompressKind::Int8, CompressKind::TopK] {
            for threads in [1usize, 2] {
                let cfg = base_cfg("tiny_native", sched, compress, threads);
                let tag = format!("{}_{}_t{threads}", cfg.sched.name(), cfg.compress.name());
                assert_resume_parity(cfg, &tag);
            }
        }
    }
}

#[test]
fn resume_parity_native_lenet() {
    // the heaviest cell on the real LeNet kernels: async buffering with
    // int8 + error-feedback uploads and 2-thread kernels
    let mut cfg = base_cfg("lenet_native", SchedKind::AsyncBuffer, CompressKind::Int8, 2);
    cfg.rounds = 4;
    cfg.dataset_size = 160;
    assert_resume_parity(cfg, "lenet_async_int8_t2");
}

/// An in-flight async straggler must span the checkpoint: the snapshot
/// carries its absolute arrival time and origin round, so after restore
/// it lands in the same round, counts as stale in the same row, and is
/// discounted by the same `(1 + landing - origin)^-alpha` weight as in
/// the uninterrupted run (global tensors stay bitwise equal).
#[test]
fn async_straggler_spans_checkpoint_and_lands_with_recorded_staleness() {
    let cfg = base_cfg("tiny_native", SchedKind::AsyncBuffer, CompressKind::Identity, 1);
    let mut full = Coordinator::new(cfg.clone(), backend(&cfg)).unwrap();
    let mut first = Coordinator::new(cfg.clone(), backend(&cfg)).unwrap();
    for _ in 0..3 {
        full.step_round().unwrap();
        first.step_round().unwrap();
    }

    let (now, events) = first.sched.clock_state();
    assert!(
        !events.is_empty(),
        "premise: K=3 of 4 must leave a straggler in flight at the checkpoint"
    );
    let path = tmp("async_midflight");
    first.checkpoint(&path).unwrap();
    drop(first);

    let mut resumed = Coordinator::restore(cfg.clone(), backend(&cfg), &path).unwrap();
    let (rnow, revents) = resumed.sched.clock_state();
    // regression pin for the wall-zero bug: the restored clock keeps the
    // absolute `now` and the stragglers' absolute arrival times — they
    // are NOT re-based against a zeroed clock, so origin-round staleness
    // survives the restore.
    assert_eq!(now.to_bits(), rnow.to_bits(), "restored clock lost absolute time");
    assert_eq!(events.len(), revents.len());
    for (a, b) in events.iter().zip(&revents) {
        assert_eq!(a.at.to_bits(), b.at.to_bits(), "in-flight arrival time diverged");
        assert_eq!((a.round, a.seq, a.client), (b.round, b.seq, b.client));
        assert!(b.at >= rnow, "restored event predates restored now");
    }

    // continue both sides — the straggler lands after the restore
    for _ in 0..3 {
        full.step_round().unwrap();
        resumed.step_round().unwrap();
    }
    let stale_total: usize = full.log.rounds.iter().map(|r| r.stale).sum();
    assert!(stale_total > 0, "premise: the async run must see stale landings");
    assert_eq!(full.global, resumed.global, "straggler landed with a different weight");
    assert_eq!(full.ledger, resumed.ledger);
    assert_eq!(full.log.rounds.len(), resumed.log.rounds.len());
    for (a, b) in full.log.rounds.iter().zip(&resumed.log.rounds) {
        assert_eq!(a.stale, b.stale, "round {}: stale landings diverged", a.round);
        assert_eq!(a.dropped, b.dropped, "round {}", a.round);
        assert_eq!(
            a.mean_loss.to_bits(),
            b.mean_loss.to_bits(),
            "round {}: loss diverged",
            a.round
        );
    }
    let _ = std::fs::remove_file(&path);
}

/// `--checkpoint-every 1` is a pure observer: every digest matches the
/// uncheckpointed run, one snapshot lands per round, and the newest
/// snapshot restores to a finished run.
#[test]
fn checkpoint_hook_writes_snapshots_without_perturbing_the_run() {
    let cfg = base_cfg("tiny_native", SchedKind::Sync, CompressKind::Identity, 1);
    let mut plain = Coordinator::new(cfg.clone(), backend(&cfg)).unwrap();
    plain.run().unwrap();

    let dir = std::env::temp_dir()
        .join(format!("fedskel_resume_{}", std::process::id()))
        .join("hook");
    let mut ckpt_cfg = cfg.clone();
    ckpt_cfg.checkpoint_dir = Some(dir.display().to_string());
    ckpt_cfg.checkpoint_every = 1;
    let mut traced = Coordinator::new(ckpt_cfg.clone(), backend(&ckpt_cfg)).unwrap();
    traced.run().unwrap();

    assert_eq!(
        params_digest(&plain.global),
        params_digest(&traced.global),
        "checkpoint writes perturbed the run"
    );
    assert_eq!(traced.registry.counter("run/checkpoints"), cfg.rounds as u64);
    for r in 1..=cfg.rounds {
        assert!(dir.join(format!("snap_round_{r}.fsnap")).is_file(), "missing round {r}");
    }

    // checkpoint knobs are excluded from the determinism key, so a
    // config without them restores snapshots written with them
    let last = dir.join(format!("snap_round_{}.fsnap", cfg.rounds));
    let resumed = Coordinator::restore(cfg.clone(), backend(&cfg), &last).unwrap();
    assert_eq!(resumed.round_idx(), cfg.rounds);
    assert_eq!(params_digest(&resumed.global), params_digest(&plain.global));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Restoring under a config that steers a different trajectory fails
/// with the typed [`SnapshotError::ConfigMismatch`]; raising `--rounds`
/// (the point of resuming) is allowed.
#[test]
fn config_mismatch_is_typed_and_rounds_are_exempt() {
    let cfg = base_cfg("tiny_native", SchedKind::Sync, CompressKind::Identity, 1);
    let mut c = Coordinator::new(cfg.clone(), backend(&cfg)).unwrap();
    c.step_round().unwrap();
    let path = tmp("mismatch");
    c.checkpoint(&path).unwrap();

    let mut other = cfg.clone();
    other.seed = 8;
    let err = Coordinator::restore(other.clone(), backend(&other), &path).unwrap_err();
    match err.downcast_ref::<SnapshotError>() {
        Some(SnapshotError::ConfigMismatch { snapshot, run }) => {
            assert!(snapshot.contains("seed=7"), "{snapshot}");
            assert!(run.contains("seed=8"), "{run}");
        }
        got => panic!("expected ConfigMismatch, got {got:?}"),
    }

    let mut more_rounds = cfg.clone();
    more_rounds.rounds = 8;
    let r = Coordinator::restore(more_rounds.clone(), backend(&more_rounds), &path).unwrap();
    assert_eq!(r.round_idx(), 1);
    let _ = std::fs::remove_file(&path);
}

/// A snapshot taken from an inline run resumes bitwise into a worker
/// pool (and the pool run's digest matches the inline one).
#[test]
fn resume_into_a_worker_pool_is_bitwise() {
    let cfg = base_cfg("tiny_native", SchedKind::Sync, CompressKind::Int8, 1);

    let mut full = Coordinator::new(cfg.clone(), backend(&cfg)).unwrap();
    full.run().unwrap();

    let mut first = Coordinator::new(cfg.clone(), backend(&cfg)).unwrap();
    for _ in 0..3 {
        first.step_round().unwrap();
    }
    let path = tmp("pool");
    first.checkpoint(&path).unwrap();
    drop(first);

    let workers: Vec<NativeBackend> = (0..2).map(|_| backend(&cfg)).collect();
    let mut resumed =
        Coordinator::restore_with_pool(cfg.clone(), backend(&cfg), workers, &path).unwrap();
    resumed.run().unwrap();

    assert_eq!(params_digest(&full.global), params_digest(&resumed.global));
    assert_eq!(full.ledger, resumed.ledger);
    let _ = std::fs::remove_file(&path);
}
