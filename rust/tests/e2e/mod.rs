//! Helpers for multi-process end-to-end tests: spawn real `fedskel`
//! binaries (via `CARGO_BIN_EXE_fedskel`), follow their stdout, and
//! guarantee no orphan processes survive a test — every [`Proc`] kills
//! its child on drop, so a failing assertion still reaps the fleet.

use std::io::BufRead;
use std::io::BufReader;
use std::path::{Path, PathBuf};
use std::process::{Child, ChildStdout, Command, Stdio};
use std::time::{Duration, Instant};

/// One spawned `fedskel` process with captured stdout.
pub struct Proc {
    pub child: Child,
    out: BufReader<ChildStdout>,
    pub captured: Vec<String>,
    name: &'static str,
}

impl Proc {
    /// Spawn `fedskel <args..>`. Stdout is piped (read it with
    /// [`Proc::expect_line`] / [`Proc::wait_success`]); stderr passes
    /// through so failures stay debuggable in test logs.
    pub fn spawn(name: &'static str, args: &[&str]) -> Proc {
        let mut child = Command::new(env!("CARGO_BIN_EXE_fedskel"))
            .args(args)
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .unwrap_or_else(|e| panic!("{name}: spawning fedskel failed: {e}"));
        let out = BufReader::new(child.stdout.take().expect("piped stdout"));
        Proc { child, out, captured: Vec::new(), name }
    }

    /// Read stdout lines until one contains `pat`; return that line.
    /// Panics (with everything captured so far) if stdout closes first.
    pub fn expect_line(&mut self, pat: &str) -> String {
        loop {
            let mut line = String::new();
            let n = self.out.read_line(&mut line).expect("reading child stdout");
            if n == 0 {
                panic!(
                    "{}: stdout closed before {pat:?} appeared; captured:\n{}",
                    self.name,
                    self.captured.join("\n")
                );
            }
            let line = line.trim_end().to_string();
            self.captured.push(line.clone());
            if line.contains(pat) {
                return line;
            }
        }
    }

    /// Drain remaining stdout, wait for exit, assert success, and return
    /// every captured line.
    pub fn wait_success(mut self) -> Vec<String> {
        loop {
            let mut line = String::new();
            if self.out.read_line(&mut line).unwrap_or(0) == 0 {
                break;
            }
            self.captured.push(line.trim_end().to_string());
        }
        let status = self.child.wait().expect("waiting for child");
        assert!(
            status.success(),
            "{} exited with {status}; captured:\n{}",
            self.name,
            self.captured.join("\n")
        );
        std::mem::take(&mut self.captured)
    }

    /// SIGKILL the child (what a crashed coordinator looks like to the
    /// rest of the fleet) and reap it.
    pub fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for Proc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// The `0x…` token from a run's `param digest:` line.
pub fn digest(lines: &[String]) -> String {
    let line = lines
        .iter()
        .find(|l| l.contains("param digest: "))
        .unwrap_or_else(|| panic!("no param digest line in:\n{}", lines.join("\n")));
    line.rsplit(' ').next().expect("digest token").to_string()
}

/// Run `fedskel train <args..>` to completion and return its digest —
/// the in-process golden the multi-process runs must reproduce.
pub fn train_digest(args: &[&str]) -> String {
    let mut argv = vec!["train"];
    argv.extend_from_slice(args);
    digest(&Proc::spawn("train", &argv).wait_success())
}

/// The `HOST:PORT` from serve's `listening on` announcement line.
pub fn listen_addr(line: &str) -> String {
    line.rsplit(' ').next().expect("addr token").to_string()
}

/// Reserve a free localhost port by binding port 0 and dropping the
/// listener — lets a SIGKILLed serve restart on the address its clients
/// are still retrying.
pub fn free_port() -> u16 {
    std::net::TcpListener::bind("127.0.0.1:0")
        .expect("bind 127.0.0.1:0")
        .local_addr()
        .expect("local addr")
        .port()
}

/// A per-test scratch directory under the target tmpdir, wiped on drop.
pub struct ScratchDir(pub PathBuf);

impl ScratchDir {
    pub fn new(tag: &str) -> ScratchDir {
        let dir = std::env::temp_dir().join(format!("fedskel_e2e_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).expect("creating scratch dir");
        ScratchDir(dir)
    }

    pub fn path(&self) -> &Path {
        &self.0
    }
}

impl Drop for ScratchDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// Block until `path` exists (a checkpoint landing, say) or `timeout`
/// elapses.
pub fn wait_for_file(path: &Path, timeout: Duration) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if path.exists() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    false
}
