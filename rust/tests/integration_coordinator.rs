//! Integration: full coordinator runs over the mock backend (method
//! semantics across rounds) and — when artifacts exist — one short
//! real-artifact FedSkel run end-to-end.

use fedskel::config::{Method, RatioAssignment, RunConfig};
use fedskel::coordinator::{Coordinator, Phase};
#[cfg(feature = "pjrt")]
use fedskel::model::Manifest;
use fedskel::runtime::mock::MockBackend;
#[cfg(feature = "pjrt")]
use fedskel::runtime::PjrtBackend;

fn mock_cfg(method: Method, rounds: usize) -> RunConfig {
    RunConfig {
        method,
        model: "toy".into(),
        num_clients: 6,
        shards_per_client: 2,
        dataset_size: 600,
        new_test_size: 60,
        rounds,
        local_steps: 2,
        updateskel_per_setskel: 3,
        eval_every: 4,
        ..RunConfig::default()
    }
}

#[test]
fn full_mock_run_all_methods() {
    for method in [Method::FedAvg, Method::FedSkel, Method::LgFedAvg, Method::FedMtl] {
        let mut c = Coordinator::new(mock_cfg(method, 8), MockBackend::toy()).unwrap();
        c.run().unwrap();
        assert_eq!(c.log.rounds.len(), 8, "{method:?}");
        assert!(c.log.last_new_acc().is_some());
        assert!(c.ledger.total_params() > 0);
        // every round logged positive simulated time
        assert!(c.log.rounds.iter().all(|r| r.sim_round_secs > 0.0));
    }
}

#[test]
fn fedskel_round_cadence_comm_pattern() {
    let mut c = Coordinator::new(mock_cfg(Method::FedSkel, 8), MockBackend::toy()).unwrap();
    c.run().unwrap();
    // SetSkel rounds move more params than UpdateSkel rounds
    let setskel: Vec<u64> = c
        .log
        .rounds
        .iter()
        .filter(|r| r.phase == "setskel")
        .map(|r| r.comm_params)
        .collect();
    let updateskel: Vec<u64> = c
        .log
        .rounds
        .iter()
        .filter(|r| r.phase == "updateskel")
        .map(|r| r.comm_params)
        .collect();
    assert_eq!(setskel.len(), 2);
    assert_eq!(updateskel.len(), 6);
    assert!(setskel[0] > updateskel[0]);
    // cadence: rounds 0,4 are setskel
    assert_eq!(c.log.rounds[0].phase, "setskel");
    assert_eq!(c.log.rounds[4].phase, "setskel");
}

#[test]
fn skeleton_stability_across_setskel_rounds() {
    // with stationary mock importance, re-selection is deterministic and
    // stable — the same skeleton is chosen at every SetSkel round.
    let mut c = Coordinator::new(mock_cfg(Method::FedSkel, 4), MockBackend::toy()).unwrap();
    c.step_round().unwrap();
    let first: Vec<Vec<Vec<i32>>> = c.clients.iter().map(|cl| cl.skeleton.clone()).collect();
    for _ in 0..4 {
        c.step_round().unwrap();
    }
    let second: Vec<Vec<Vec<i32>>> = c.clients.iter().map(|cl| cl.skeleton.clone()).collect();
    assert_eq!(first, second);
}

#[test]
fn ratio_assignment_modes() {
    let cases: Vec<(RatioAssignment, fn(&[f64]) -> bool)> = vec![
        (RatioAssignment::Fixed(0.5), |rs| {
            rs.iter().all(|&r| (r - 0.5).abs() < 1e-9)
        }),
        (RatioAssignment::Equidistant { lo: 0.1, hi: 1.0 }, |rs| {
            rs.windows(2).all(|w| w[1] > w[0])
        }),
        (RatioAssignment::Linear, |rs| {
            rs.last().map(|&r| (r - 1.0).abs() < 1e-9).unwrap_or(false)
        }),
    ];
    for (assign, check) in cases {
        let mut cfg = mock_cfg(Method::FedSkel, 2);
        cfg.ratio_assignment = assign;
        let c = Coordinator::new(cfg, MockBackend::toy()).unwrap();
        let rs: Vec<f64> = c.clients.iter().map(|cl| cl.ratio).collect();
        assert!(check(&rs), "{assign:?}: {rs:?}");
    }
}

#[test]
fn phases_are_full_for_baselines() {
    let c = Coordinator::new(mock_cfg(Method::FedAvg, 2), MockBackend::toy()).unwrap();
    assert_eq!(c.phase_of(0), Phase::Full);
    assert_eq!(c.phase_of(5), Phase::Full);
}

// ---------------------------------------------------------- real backend

#[cfg(feature = "pjrt")]
#[test]
fn short_real_fedskel_run_learns() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let manifest = Manifest::load(dir).unwrap();
    let cfg = RunConfig {
        method: Method::FedSkel,
        model: "lenet_smnist".into(),
        num_clients: 4,
        shards_per_client: 2,
        dataset_size: 400,
        new_test_size: 128,
        rounds: 5,
        local_steps: 3,
        updateskel_per_setskel: 3,
        eval_every: 0,
        lr: 0.08,
        artifacts_dir: dir.into(),
        ..RunConfig::default()
    };
    let backend = PjrtBackend::new(&manifest, "lenet_smnist").unwrap();
    let mut c = Coordinator::new(cfg, backend).unwrap();
    c.run().unwrap();
    let first_loss = c.log.rounds.first().unwrap().mean_loss;
    let last_loss = c.log.rounds.last().unwrap().mean_loss;
    assert!(last_loss < first_loss, "loss {first_loss} -> {last_loss}");
    let local = c.log.last_local_acc().unwrap();
    assert!(local > 0.3, "local acc {local} too low after 5 rounds");
}
