//! Integration: the kernel-tier determinism contract at the public API.
//!
//! The SIMD tier must be a *bitwise* drop-in for the scalar tier — same
//! digests at any thread count and either tier — because every kernel
//! walks each output element's reduction axis in the same ascending
//! order regardless of how work is sharded or which register layout the
//! inner loop uses. These tests pin that contract end-to-end: raw
//! p-wrappers on ragged shapes, then a whole `train_step` on both native
//! models. The int8 forward path is the deliberate exception (it
//! approximates f32), so it gets a *bounded-error* check instead, plus a
//! pin that server eval stays f32-exact.

use fedskel::kernels::{
    maxpool2_fwd, pgemm, pgemm_bt_a, pim2col, pmaxpool2_fwd, Conv2d, KernelTier, Parallelism,
    Precision,
};
use fedskel::model::{init_params, params_digest};
use fedskel::runtime::native::{prefix_skeleton, NativeBackend, NativeModel};
use fedskel::runtime::step::Backend;
use fedskel::util::Rng;

const THREADS: [usize; 3] = [1, 2, 7];
const TIERS: [KernelTier; 2] = [KernelTier::Scalar, KernelTier::Simd];

fn data(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() * 0.5).collect()
}

/// Non-zero output prefill: pins `+=` accumulate semantics (a kernel
/// that cleared its output first would still match on zeroed buffers).
fn prefill(n: usize) -> Vec<f32> {
    (0..n).map(|i| (i % 7) as f32 * 0.125 - 0.375).collect()
}

#[test]
fn pgemm_is_bitwise_tier_and_thread_invariant_on_ragged_shapes() {
    // ragged in every dimension: unit, sub-panel, off-by-one over the
    // k-tile (257 > KC=256), non-multiples of the 8-wide column panel
    for &(m, k, n) in &[(1, 1, 1), (3, 5, 4), (7, 300, 2), (13, 257, 31), (37, 150, 96)] {
        let a = data(m * k, 0xA0 + m as u64);
        let b = data(k * n, 0xB0 + n as u64);
        let mut want = prefill(m * n);
        pgemm(Parallelism::serial(), m, k, n, &a, &b, &mut want);
        for &t in &THREADS {
            for &tier in &TIERS {
                let mut got = prefill(m * n);
                pgemm(Parallelism::new(t).with_tier(tier), m, k, n, &a, &b, &mut got);
                assert_eq!(got, want, "pgemm {m}x{k}x{n} t{t} {:?}", tier);
            }
        }
    }
}

#[test]
fn pgemm_bt_a_is_bitwise_tier_and_thread_invariant() {
    // (m, k, n): dW^T = B^T·A with B [m,n], A [m,k] — n is the sharded
    // output-column axis, k crosses the 16-wide accumulator block
    for &(m, k, n) in &[(6, 10, 3), (37, 50, 8), (640, 33, 13), (9, 1, 4)] {
        let a = data(m * k, 0xC0 + k as u64);
        let b = data(m * n, 0xD0 + n as u64);
        let mut want = prefill(n * k);
        pgemm_bt_a(Parallelism::serial(), m, k, n, &a, &b, &mut want);
        for &t in &THREADS {
            for &tier in &TIERS {
                let mut got = prefill(n * k);
                pgemm_bt_a(Parallelism::new(t).with_tier(tier), m, k, n, &a, &b, &mut got);
                assert_eq!(got, want, "pgemm_bt_a {m}x{k}x{n} t{t} {:?}", tier);
            }
        }
    }
}

#[test]
fn pim2col_and_pmaxpool_are_bitwise_tier_and_thread_invariant() {
    let conv = Conv2d { in_h: 14, in_w: 11, cin: 3, cout: 4, kh: 5, kw: 3 };
    let batch = 9;
    let x = data(batch * conv.in_numel(), 0xE0);
    let plen = conv.rows(batch) * conv.patch_len();
    let mut want = vec![0.0f32; plen];
    pim2col(Parallelism::serial(), &conv, batch, &x, &mut want);
    // pooling over the conv input volume (even dims required: crop)
    let (ph, pw, pc) = (14, 10, 3);
    let px = data(batch * ph * pw * pc, 0xE1);
    let mut pool_want = vec![0.0f32; batch * (ph / 2) * (pw / 2) * pc];
    let mut arg_want = vec![0u32; pool_want.len()];
    maxpool2_fwd(batch, ph, pw, pc, &px, &mut pool_want, &mut arg_want);
    for &t in &THREADS {
        for &tier in &TIERS {
            let par = Parallelism::new(t).with_tier(tier);
            let mut got = vec![0.0f32; plen];
            pim2col(par, &conv, batch, &x, &mut got);
            assert_eq!(got, want, "pim2col t{t} {:?}", tier);
            let mut pool_got = vec![0.0f32; pool_want.len()];
            let mut arg_got = vec![0u32; arg_want.len()];
            pmaxpool2_fwd(par, batch, ph, pw, pc, &px, &mut pool_got, &mut arg_got);
            assert_eq!(pool_got, pool_want, "pmaxpool t{t} {:?}", tier);
            assert_eq!(arg_got, arg_want, "pmaxpool argmax t{t} {:?}", tier);
        }
    }
}

fn batch_for(model: &NativeModel, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let numel: usize = model.spec.input_shape.iter().product();
    let x = data(model.spec.train_batch * numel, seed);
    let y = (0..model.spec.train_batch).map(|i| (i % model.spec.num_classes) as i32).collect();
    (x, y)
}

/// One skeleton-sliced train step on `model` under (tier, threads);
/// returns the updated-param digest and the step loss.
fn step_digest(model: NativeModel, tier: KernelTier, threads: usize) -> (u64, f32) {
    let r = *model.spec.train_buckets().iter().min().unwrap();
    let ks = model.spec.train_artifact(r).unwrap().k.clone();
    let skel = prefix_skeleton(&ks);
    let (x, y) = batch_for(&model, 0xF00D);
    let params = init_params(&model.spec, 7);
    let mut backend = NativeBackend::new(
        model.with_parallelism(Parallelism::new(threads).with_tier(tier)),
    );
    let out = backend.train_step(r, &params, &params, &x, &y, &skel, 0.05, 0.0).unwrap();
    (params_digest(&out.params), out.loss)
}

#[test]
fn train_step_digest_is_tier_and_thread_invariant_on_both_models() {
    for mk in [NativeModel::lenet as fn() -> NativeModel, NativeModel::cifar] {
        let (want_digest, want_loss) = step_digest(mk(), KernelTier::Scalar, 1);
        for &t in &THREADS {
            for &tier in &TIERS {
                let (digest, loss) = step_digest(mk(), tier, t);
                assert_eq!(digest, want_digest, "{} t{t} {:?}", mk().spec.name, tier);
                assert_eq!(loss.to_bits(), want_loss.to_bits());
            }
        }
    }
}

#[test]
fn int8_forward_is_bounded_error_and_eval_stays_f32() {
    let model = NativeModel::tiny();
    let (x, _y) = batch_for(&model, 0xBEEF);
    let params = init_params(&model.spec, 11);
    let batch = model.spec.train_batch;
    let f32_trace = model.forward(&params, &x, batch).unwrap();
    let int8_model = model.clone().with_precision(Precision::Int8);
    let int8_trace = int8_model.forward(&params, &x, batch).unwrap();
    let (mut max_err, mut max_ref) = (0.0f32, 0.0f32);
    for (a, b) in f32_trace.logits().iter().zip(int8_trace.logits()) {
        max_err = max_err.max((a - b).abs());
        max_ref = max_ref.max(a.abs());
    }
    assert!(max_err > 0.0, "int8 path was not exercised");
    assert!(max_err <= 0.1 * max_ref + 1e-3, "max_err {max_err} vs max_ref {max_ref}");
    // eval on an int8 backend is bitwise the f32 eval: the server always
    // scores with full-precision forwards
    let numel: usize = model.spec.input_shape.iter().product();
    let ex = data(model.spec.eval_batch * numel, 0xEA7);
    let mut f32_backend = NativeBackend::new(model.clone());
    let mut int8_backend = NativeBackend::new(model);
    int8_backend.set_precision(Precision::Int8);
    let want = f32_backend.eval_logits(&params, &ex).unwrap();
    let got = int8_backend.eval_logits(&params, &ex).unwrap();
    assert_eq!(want.data(), got.data());
    assert_eq!(int8_backend.precision(), Precision::Int8, "precision must be restored");
}
