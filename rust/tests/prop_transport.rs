//! Property tests over the transport wire codec (seeded random-case
//! harness, same discipline as prop_invariants.rs): encode→decode is the
//! identity for every payload kind at f32, byte sizes match the analytic
//! accounting exactly, and the coordinator's measured ledger agrees with
//! the pure-accounting path.

use fedskel::comm::{params_moved, ExchangeKind};
use fedskel::compress::block_roundtrip;
use fedskel::config::{Method, RunConfig};
use fedskel::coordinator::Coordinator;
use fedskel::model::{init_params, Params};
use fedskel::runtime::mock::{toy_spec, MockBackend};
use fedskel::tensor::Tensor;
use fedskel::transport::wire::{self, BlockPlan, FrameOpts, Quant, RoundMsg, WirePayload};
use fedskel::transport::TransportKind;
use fedskel::util::Rng;

fn cases(n: u64, mut prop: impl FnMut(&mut Rng)) {
    for seed in 0..n {
        let mut rng = Rng::new(0x71A5_0000 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

fn rand_params(rng: &mut Rng) -> Params {
    let spec = toy_spec();
    let mut ps = init_params(&spec, rng.next_u64());
    for t in &mut ps {
        for v in t.data_mut() {
            *v = rng.normal() * 2.0;
        }
    }
    ps
}

fn rand_skeleton(rng: &mut Rng, channels: usize) -> Vec<i32> {
    let k = rng.below(channels + 1); // 0..=channels, k==0 and k==C both legal
    rng.choose_k(channels, k).iter().map(|&c| c as i32).collect()
}

#[test]
fn prop_full_roundtrip_identity() {
    let spec = toy_spec();
    cases(50, |rng| {
        let msg = RoundMsg {
            round: rng.below(10_000) as u32,
            client: rng.below(1000) as u32,
            weight: rng.uniform() as f64 * 500.0,
            payload: WirePayload::full(&rand_params(rng)),
        };
        let frame = wire::encode(&msg, Quant::F32);
        assert_eq!(frame.len(), wire::encoded_len(&spec, &ExchangeKind::Full, Quant::F32));
        assert_eq!(wire::decode(&spec, &frame).unwrap(), msg);
    });
}

#[test]
fn prop_skeleton_roundtrip_identity_all_k() {
    let spec = toy_spec();
    let channels = spec.prunable[0].channels;
    cases(100, |rng| {
        let skel = vec![rand_skeleton(rng, channels)];
        let params = rand_params(rng);
        let msg = RoundMsg {
            round: 1,
            client: rng.below(64) as u32,
            weight: 1.0,
            payload: WirePayload::skeleton(&spec, &params, &skel).unwrap(),
        };
        let frame = wire::encode(&msg, Quant::F32);
        let kind = ExchangeKind::Skeleton(vec![skel[0].len()]);
        assert_eq!(frame.len(), wire::encoded_len(&spec, &kind, Quant::F32));
        let back = wire::decode(&spec, &frame).unwrap();
        assert_eq!(back, msg);
        // the payload carries exactly what the ledger charges for
        assert_eq!(back.payload.params_carried(), params_moved(&spec, &kind));
    });
}

#[test]
fn prop_subset_roundtrip_identity() {
    let spec = toy_spec();
    cases(80, |rng| {
        let n = rng.below(spec.params.len() + 1);
        let ids = rng.choose_k(spec.params.len(), n);
        let params = rand_params(rng);
        let msg = RoundMsg {
            round: 2,
            client: 0,
            weight: 3.0,
            payload: WirePayload::subset(&spec, &params, &ids).unwrap(),
        };
        let frame = wire::encode(&msg, Quant::F32);
        let kind = ExchangeKind::ParamSubset(ids);
        assert_eq!(frame.len(), wire::encoded_len(&spec, &kind, Quant::F32));
        assert_eq!(wire::decode(&spec, &frame).unwrap(), msg);
    });
}

#[test]
fn prop_overlay_roundtrip_recovers_sent_channels() {
    // download semantics: whatever the payload carried lands bit-exact in
    // the target; everything else is untouched.
    let spec = toy_spec();
    let channels = spec.prunable[0].channels;
    cases(80, |rng| {
        let src = rand_params(rng);
        let base = rand_params(rng);
        let skel = vec![rand_skeleton(rng, channels)];
        let payload = WirePayload::skeleton(&spec, &src, &skel).unwrap();
        let frame = wire::encode(
            &RoundMsg { round: 0, client: 0, weight: 0.0, payload },
            Quant::F32,
        );
        let decoded = wire::decode(&spec, &frame).unwrap();
        let mut target = base.clone();
        decoded.payload.overlay_into(&spec, &mut target).unwrap();
        let sel: std::collections::BTreeSet<i32> = skel[0].iter().copied().collect();
        let rows = src[0].len() / channels;
        for c in 0..channels {
            let from = if sel.contains(&(c as i32)) { &src } else { &base };
            for r in 0..rows {
                assert_eq!(target[0].data()[r * channels + c], from[0].data()[r * channels + c]);
            }
            assert_eq!(target[1].data()[c], from[1].data()[c]);
        }
        assert_eq!(target[2], src[2]);
        assert_eq!(target[3], src[3]);
    });
}

#[test]
fn prop_quantized_sizes_exact_and_smaller() {
    let spec = toy_spec();
    let channels = spec.prunable[0].channels;
    cases(40, |rng| {
        let params = rand_params(rng);
        // k ≥ 1: with empty value blocks int8's per-block scale overhead
        // can exceed its 1-byte/value savings on the tiny toy model
        let k = 1 + rng.below(channels);
        let skel: Vec<Vec<i32>> =
            vec![rng.choose_k(channels, k).iter().map(|&c| c as i32).collect()];
        let payload = WirePayload::skeleton(&spec, &params, &skel).unwrap();
        let msg = RoundMsg { round: 0, client: 0, weight: 1.0, payload };
        let kind = ExchangeKind::Skeleton(vec![skel[0].len()]);
        let mut last = usize::MAX;
        for q in [Quant::F32, Quant::F16, Quant::Int8] {
            let frame = wire::encode(&msg, q);
            assert_eq!(frame.len(), wire::encoded_len(&spec, &kind, q), "{q:?}");
            assert!(frame.len() < last, "{q:?} must shrink the frame");
            last = frame.len();
            // still decodable
            wire::decode(&spec, &frame).unwrap();
        }
    });
}

#[test]
fn prop_planned_blocks_decode_to_the_host_side_roundtrip() {
    // for ANY per-block plan (dense f32/f16/int8 or top-k sparse), the
    // values the decoder reconstructs equal compress::block_roundtrip
    // bitwise — the identity the error-feedback residuals stand on.
    let spec = toy_spec();
    cases(60, |rng| {
        let params = rand_params(rng);
        let plans: Vec<BlockPlan> = spec
            .params
            .iter()
            .map(|p| {
                let n = p.numel();
                match rng.below(4) {
                    0 => BlockPlan::dense(Quant::F32),
                    1 => BlockPlan::dense(Quant::F16),
                    2 => BlockPlan::dense(Quant::Int8),
                    _ => {
                        let k = 1 + rng.below(n);
                        let mut idx: Vec<u32> =
                            rng.choose_k(n, k).iter().map(|&i| i as u32).collect();
                        idx.sort_unstable();
                        BlockPlan { quant: Quant::F32, idx: Some(idx) }
                    }
                }
            })
            .collect();
        let msg = RoundMsg {
            round: 0,
            client: 0,
            weight: 1.0,
            payload: WirePayload::full(&params),
        };
        let frame = wire::encode_opts(
            &msg,
            &FrameOpts { quant: Quant::F32, delta: true, plans: Some(&plans) },
        )
        .unwrap();
        let (back, delta) = wire::decode_frame(&spec, &frame, None).unwrap();
        assert!(delta, "DELTA flag must survive the roundtrip");
        assert!(wire::decode(&spec, &frame).is_err(), "plain decode must refuse delta frames");
        let WirePayload::Full(ps) = &back.payload else { panic!("wrong kind") };
        for ((t, orig), plan) in ps.iter().zip(&params).zip(&plans) {
            assert_eq!(t.data(), &block_roundtrip(orig.data(), plan)[..]);
        }
    });
}

#[test]
fn prop_anchor_delta_reconstruction_is_bitwise() {
    // download delta-vs-anchor: whatever random subset of positions
    // changed, the receiver reconstructs the sender's params exactly.
    let spec = toy_spec();
    cases(60, |rng| {
        let anchor = rand_params(rng);
        let mut current = anchor.clone();
        for t in &mut current {
            let n = t.len();
            let m = rng.below(n + 1);
            for i in rng.choose_k(n, m) {
                t.data_mut()[i] = rng.normal() * 3.0;
            }
        }
        let payload = WirePayload::anchor_delta(&spec, &anchor, &current, Quant::F32).unwrap();
        let msg = RoundMsg { round: 0, client: 0, weight: 0.0, payload };
        let frame = wire::encode(&msg, Quant::F32);
        let (back, delta) = wire::decode_frame(&spec, &frame, Some(&anchor)).unwrap();
        assert!(!delta);
        assert_eq!(back.payload, WirePayload::Full(current.clone()));
    });
}

#[test]
fn coordinator_ledger_matches_analytic_frame_sizes() {
    // a real FedAvg run's measured wire bytes == clients × rounds × 2
    // full frames (loopback, f32) — the coordinator and the pure
    // accounting path agree exactly.
    let spec = toy_spec();
    let cfg = RunConfig {
        method: Method::FedAvg,
        model: "toy".into(),
        num_clients: 4,
        shards_per_client: 2,
        dataset_size: 400,
        new_test_size: 64,
        rounds: 3,
        local_steps: 2,
        eval_every: 0,
        transport: TransportKind::Loopback,
        ..RunConfig::default()
    };
    let mut c = Coordinator::new(cfg, MockBackend::toy()).unwrap();
    c.run().unwrap();
    let frame = wire::encoded_len(&spec, &ExchangeKind::Full, Quant::F32) as u64;
    assert_eq!(c.ledger.total_wire_bytes(), 4 * 3 * 2 * frame);
    assert_eq!(c.ledger.total_params(), 4 * 3 * 2 * spec.num_params as u64);
}

#[test]
fn pooled_coordinator_matches_inline_wire_accounting() {
    let mk_cfg = || RunConfig {
        method: Method::FedSkel,
        model: "toy".into(),
        num_clients: 6,
        shards_per_client: 2,
        dataset_size: 600,
        new_test_size: 64,
        rounds: 6,
        local_steps: 2,
        updateskel_per_setskel: 2,
        eval_every: 0,
        transport: TransportKind::Loopback,
        ..RunConfig::default()
    };
    let mut inline = Coordinator::new(mk_cfg(), MockBackend::toy()).unwrap();
    inline.run().unwrap();
    let workers: Vec<MockBackend> = (0..4).map(|_| MockBackend::toy()).collect();
    let mut pooled = Coordinator::with_pool(mk_cfg(), MockBackend::toy(), workers).unwrap();
    pooled.run().unwrap();
    assert_eq!(pooled.workers(), 4);
    assert_eq!(inline.ledger.total_wire_bytes(), pooled.ledger.total_wire_bytes());
    assert_eq!(inline.global, pooled.global);
}

#[test]
fn tensor_gather_matches_wire_gather() {
    // the codec's row gather agrees with the host-side Tensor helper
    let t = Tensor::from_vec(&[2, 4], vec![0., 1., 2., 3., 4., 5., 6., 7.]).unwrap();
    let g = t.gather_cols(&[1, 3]).unwrap();
    let spec = toy_spec();
    let mut params = init_params(&spec, 0);
    params[0] = Tensor::from_vec(&[8, 4], (0..32).map(|v| v as f32).collect()).unwrap();
    let p = WirePayload::skeleton(&spec, &params, &[vec![1, 3]]).unwrap();
    let WirePayload::Skeleton { layers, .. } = &p else { panic!() };
    assert_eq!(&layers[0].weight[0..2], g.data().get(0..2).unwrap());
}
