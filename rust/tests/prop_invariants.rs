//! Property-based tests over coordinator invariants (routing, batching,
//! aggregation, selection, comm accounting).
//!
//! The offline registry lacks `proptest`, so this uses a seeded random-case
//! harness (`cases`): N deterministic random cases per property with the
//! failing seed printed on panic — same discipline, fewer features
//! (DESIGN.md §3 records the substitution).

use fedskel::aggregate::{self, Update};
use fedskel::comm::{params_moved, ExchangeKind};
use fedskel::data::shard::{non_iid_shards, Batcher};
use fedskel::data::synthetic::{Dataset, DatasetKind};
use fedskel::model::spec::PrunableSpec;
use fedskel::model::Params;
use fedskel::skeleton::{select_skeleton, top_k_channels, RatioPolicy};
use fedskel::tensor::Tensor;
use fedskel::util::Rng;

/// Run `n` seeded cases of a property.
fn cases(n: u64, mut prop: impl FnMut(&mut Rng)) {
    for seed in 0..n {
        let mut rng = Rng::new(0xFED5_0000 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

fn rand_params(rng: &mut Rng, rows: usize, channels: usize, extra: usize) -> Params {
    let mut w = Tensor::zeros(&[rows, channels]);
    w.data_mut().iter_mut().for_each(|v| *v = rng.normal());
    let mut b = Tensor::zeros(&[channels]);
    b.data_mut().iter_mut().for_each(|v| *v = rng.normal());
    let mut h = Tensor::zeros(&[extra]);
    h.data_mut().iter_mut().for_each(|v| *v = rng.normal());
    vec![w, b, h]
}

fn prunable(channels: usize) -> Vec<PrunableSpec> {
    vec![PrunableSpec { name: "l0".into(), channels, weight_param: 0, bias_param: 1 }]
}

// ---------------------------------------------------------------- top-k

#[test]
fn prop_topk_returns_k_sorted_valid_channels() {
    cases(200, |rng| {
        let n = 1 + rng.below(64);
        let k = 1 + rng.below(n);
        let imp: Vec<f64> = (0..n).map(|_| rng.normal() as f64).collect();
        let sel = top_k_channels(&imp, k);
        assert_eq!(sel.len(), k);
        assert!(sel.windows(2).all(|w| w[0] < w[1]), "sorted unique");
        assert!(sel.iter().all(|&c| (c as usize) < n));
        // every selected channel's importance ≥ every unselected one's
        let selected: std::collections::BTreeSet<i32> = sel.iter().copied().collect();
        let min_in = sel.iter().map(|&c| imp[c as usize]).fold(f64::MAX, f64::min);
        let max_out = (0..n)
            .filter(|c| !selected.contains(&(*c as i32)))
            .map(|c| imp[c])
            .fold(f64::MIN, f64::max);
        if max_out != f64::MIN {
            assert!(min_in >= max_out, "top-k dominance");
        }
    });
}

#[test]
fn prop_select_skeleton_respects_layer_sizes() {
    cases(100, |rng| {
        let layers = 1 + rng.below(5);
        let mut means = Vec::new();
        let mut ks = Vec::new();
        for _ in 0..layers {
            let c = 1 + rng.below(32);
            means.push((0..c).map(|_| rng.uniform() as f64).collect::<Vec<_>>());
            ks.push(1 + rng.below(c));
        }
        let skel = select_skeleton(&means, &ks).unwrap();
        for (s, &k) in skel.iter().zip(&ks) {
            assert_eq!(s.len(), k);
        }
    });
}

// ------------------------------------------------------------ aggregation

#[test]
fn prop_fedavg_preserves_constant_consensus() {
    // if every client sends the same params, the average is those params
    cases(100, |rng| {
        let rows = 1 + rng.below(6);
        let ch = 1 + rng.below(8);
        let shared = rand_params(rng, rows, ch, 3);
        let global = rand_params(rng, rows, ch, 3);
        let n = 1 + rng.below(5);
        let ups: Vec<Update> = (0..n)
            .map(|i| Update {
                client: i,
                weight: 1.0 + rng.below(100) as f64,
                params: shared.clone(),
                skeleton: vec![],
            })
            .collect();
        let out = aggregate::fedavg(&global, &ups).unwrap();
        for (o, s) in out.iter().zip(&shared) {
            for (a, b) in o.data().iter().zip(s.data()) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    });
}

#[test]
fn prop_fedavg_bounded_by_extremes() {
    // averaged values lie within [min, max] over clients, elementwise
    cases(100, |rng| {
        let rows = 1 + rng.below(4);
        let ch = 1 + rng.below(6);
        let global = rand_params(rng, rows, ch, 2);
        let n = 2 + rng.below(4);
        let ups: Vec<Update> = (0..n)
            .map(|i| Update {
                client: i,
                weight: 1.0 + rng.uniform() as f64 * 9.0,
                params: rand_params(rng, rows, ch, 2),
                skeleton: vec![],
            })
            .collect();
        let out = aggregate::fedavg(&global, &ups).unwrap();
        for pi in 0..out.len() {
            for e in 0..out[pi].len() {
                let v = out[pi].data()[e];
                let lo = ups.iter().map(|u| u.params[pi].data()[e]).fold(f32::MAX, f32::min);
                let hi = ups.iter().map(|u| u.params[pi].data()[e]).fold(f32::MIN, f32::max);
                assert!(v >= lo - 1e-4 && v <= hi + 1e-4, "convexity at [{pi}][{e}]");
            }
        }
    });
}

#[test]
fn prop_fedskel_uncovered_channels_keep_global() {
    cases(150, |rng| {
        let rows = 1 + rng.below(5);
        let ch = 2 + rng.below(10);
        let global = rand_params(rng, rows, ch, 2);
        let n = 1 + rng.below(4);
        let ups: Vec<Update> = (0..n)
            .map(|i| {
                let k = 1 + rng.below(ch);
                let skel: Vec<i32> = rng.choose_k(ch, k).iter().map(|&c| c as i32).collect();
                Update {
                    client: i,
                    weight: 1.0 + rng.below(20) as f64,
                    params: rand_params(rng, rows, ch, 2),
                    skeleton: vec![skel],
                }
            })
            .collect();
        let out = aggregate::fedskel_aggregate(&global, &ups, &prunable(ch)).unwrap();
        let covered: std::collections::BTreeSet<i32> =
            ups.iter().flat_map(|u| u.skeleton[0].iter().copied()).collect();
        for c in 0..ch {
            if !covered.contains(&(c as i32)) {
                for r in 0..rows {
                    assert_eq!(
                        out[0].data()[r * ch + c],
                        global[0].data()[r * ch + c],
                        "uncovered channel {c} must keep global"
                    );
                }
                assert_eq!(out[1].data()[c], global[1].data()[c]);
            }
        }
    });
}

#[test]
fn prop_fedskel_fullcoverage_equals_fedavg() {
    cases(100, |rng| {
        let rows = 1 + rng.below(4);
        let ch = 1 + rng.below(8);
        let global = rand_params(rng, rows, ch, 2);
        let n = 1 + rng.below(4);
        let full: Vec<i32> = (0..ch as i32).collect();
        let ups: Vec<Update> = (0..n)
            .map(|i| Update {
                client: i,
                weight: 1.0 + rng.below(9) as f64,
                params: rand_params(rng, rows, ch, 2),
                skeleton: vec![full.clone()],
            })
            .collect();
        let skel = aggregate::fedskel_aggregate(&global, &ups, &prunable(ch)).unwrap();
        let avg = aggregate::fedavg(&global, &ups).unwrap();
        for (a, b) in skel.iter().zip(&avg) {
            for (x, y) in a.data().iter().zip(b.data()) {
                assert!((x - y).abs() < 1e-4);
            }
        }
    });
}

#[test]
fn prop_download_roundtrip_identity_outside_skeleton() {
    // a FedSkel client's non-skeleton channels are invisible to downloads:
    // after a skeleton download, skeleton channels carry global values and
    // everything else keeps the client's local values.
    cases(100, |rng| {
        let rows = 1 + rng.below(4);
        let ch = 2 + rng.below(8);
        let global = rand_params(rng, rows, ch, 2);
        let mut local = rand_params(rng, rows, ch, 2);
        let local_orig = local.clone();
        let k = 1 + rng.below(ch);
        let skel: Vec<Vec<i32>> = vec![rng.choose_k(ch, k).iter().map(|&c| c as i32).collect()];
        aggregate::apply_download(&mut local, &global, &prunable(ch), &skel, None).unwrap();
        let sel: std::collections::BTreeSet<i32> = skel[0].iter().copied().collect();
        for c in 0..ch {
            for r in 0..rows {
                let got = local[0].data()[r * ch + c];
                let want = if sel.contains(&(c as i32)) {
                    global[0].data()[r * ch + c]
                } else {
                    local_orig[0].data()[r * ch + c]
                };
                assert_eq!(got, want);
            }
        }
        // non-prunable tensor downloaded in full
        assert_eq!(local[2], global[2]);
    });
}

// --------------------------------------------------------------- sharding

#[test]
fn prop_shards_partition_exactly() {
    cases(40, |rng| {
        let clients = 2 + rng.below(10);
        let spc = 1 + rng.below(3);
        let n = clients * spc * (5 + rng.below(20));
        let data = Dataset::generate(DatasetKind::Smnist, n, rng.next_u64());
        let splits = non_iid_shards(&data, clients, spc, 0.2, rng.next_u64()).unwrap();
        let mut seen = vec![false; n];
        for s in &splits {
            for &i in s.train.iter().chain(s.test.iter()) {
                assert!(!seen[i], "sample {i} assigned twice");
                seen[i] = true;
            }
        }
        let shard_sz = n / (clients * spc);
        let used = clients * spc * shard_sz;
        assert_eq!(seen.iter().filter(|&&b| b).count(), used);
    });
}

#[test]
fn prop_batcher_visits_everything_each_epoch() {
    cases(60, |rng| {
        let n = 1 + rng.below(50);
        let batch = 1 + rng.below(16);
        let mut b = Batcher::new((0..n).collect(), batch, rng.next_u64());
        // one epoch = ceil(n/batch) batches covers all indices at least
        // once (plus one wrap batch for the pad path)
        let mut seen = std::collections::BTreeSet::new();
        let batches = n.div_ceil(batch) + 1;
        for _ in 0..batches {
            for i in b.next_batch() {
                seen.insert(i);
            }
        }
        assert_eq!(seen.len(), n);
    });
}

// ------------------------------------------------------------------- comm

#[test]
fn prop_skeleton_comm_monotone_in_k() {
    use fedskel::runtime::mock::toy_spec;
    let spec = toy_spec();
    cases(100, |rng| {
        let ch = spec.prunable[0].channels;
        let k1 = 1 + rng.below(ch);
        let k2 = 1 + rng.below(ch);
        let (lo, hi) = (k1.min(k2), k1.max(k2));
        let p_lo = params_moved(&spec, &ExchangeKind::Skeleton(vec![lo]));
        let p_hi = params_moved(&spec, &ExchangeKind::Skeleton(vec![hi]));
        assert!(p_lo <= p_hi);
        assert!(p_hi <= spec.num_params);
    });
}

#[test]
fn prop_ratio_policies_in_unit_interval() {
    cases(100, |rng| {
        let n = 1 + rng.below(20);
        let caps: Vec<f64> = (0..n).map(|_| 0.05 + rng.uniform() as f64).collect();
        for policy in [
            RatioPolicy::LinearCapability { min_ratio: 0.1 },
            RatioPolicy::Equidistant { lo: 0.1, hi: 1.0 },
            RatioPolicy::Fixed(0.3),
        ] {
            let rs = policy.assign(&caps).unwrap();
            assert_eq!(rs.len(), n);
            assert!(rs.iter().all(|r| (0.05..=1.0).contains(r)), "{policy:?} {rs:?}");
        }
    });
}
