//! Snapshot file-format robustness from the public API.
//!
//! The contract under test (docs/CHECKPOINT.md): a corrupt, truncated,
//! version-skewed, or extended snapshot file must fail with a *typed*
//! [`SnapshotError`] — never a panic, and never a silently-degraded
//! resume. Plus the round-trip property: `decode(encode(s)) == s`
//! bitwise for arbitrary client state, including empty and ragged
//! error-feedback residuals and NaN losses.

use fedskel::comm::CommLedger;
use fedskel::config::RunConfig;
use fedskel::kernels::Precision;
use fedskel::metrics::RoundLog;
use fedskel::model::{init_params, ModelSpec, Params};
use fedskel::runtime::mock::toy_spec;
use fedskel::sched::Completion;
use fedskel::snapshot::{
    determinism_key, ClientSnap, DeviceSnap, PendingSnap, Snapshot, SnapshotError, VERSION,
};
use fedskel::transport::wire::{self, WirePayload};

/// Tiny deterministic generator (LCG) — no host entropy in tests.
struct Lcg(u64);

impl Lcg {
    fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n.max(1)
    }

    fn f32(&mut self) -> f32 {
        // raw bit patterns, NaN/inf excluded so PartialEq can compare
        loop {
            let v = f32::from_bits(self.next_u64() as u32);
            if v.is_finite() {
                return v;
            }
        }
    }

    fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }
}

fn arbitrary_client(spec: &ModelSpec, rng: &mut Lcg, id: u32) -> ClientSnap {
    // ragged residual: 0..4 blocks of 0..5 values each (empty blocks and
    // the all-empty layout both legal)
    let blocks = rng.below(4) as usize;
    let ef_residual: Vec<Vec<f32>> = (0..blocks)
        .map(|_| (0..rng.below(5)).map(|_| rng.f32()).collect())
        .collect();
    let skeleton: Vec<Vec<i32>> = (0..rng.below(3))
        .map(|_| (0..rng.below(6)).map(|_| rng.below(8) as i32).collect())
        .collect();
    ClientSnap {
        id,
        capability: rng.f64(),
        ratio: rng.f64(),
        bucket: rng.below(100) as u32,
        last_loss_bits: if rng.below(2) == 0 { f32::NAN.to_bits() } else { rng.f32().to_bits() },
        skeleton,
        local_params: init_params(spec, rng.next_u64()),
        importance_sums: (0..rng.below(3))
            .map(|_| (0..rng.below(4)).map(|_| rng.f64() - 0.5).collect())
            .collect(),
        importance_batches: rng.below(1000),
        batcher_indices: (0..rng.below(20)).map(|_| rng.below(512) as u32).collect(),
        batcher_batch: 1 + rng.below(64) as u32,
        batcher_cursor: rng.below(1 << 20),
        batcher_rng_state: rng.next_u64(),
        batcher_rng_spare: if rng.below(2) == 0 { None } else { Some(rng.f32()) },
        ef_residual,
    }
}

fn arbitrary_snapshot(spec: &ModelSpec, seed: u64) -> Snapshot {
    let mut rng = Lcg(seed);
    let n_clients = 1 + rng.below(4) as usize;
    Snapshot {
        determinism_key: determinism_key(&RunConfig::default()),
        round_idx: rng.below(100),
        rng_state: rng.next_u64(),
        rng_spare: if rng.below(2) == 0 { None } else { Some(rng.f32()) },
        global: init_params(spec, rng.next_u64()),
        clients: (0..n_clients).map(|i| arbitrary_client(spec, &mut rng, i as u32)).collect(),
        fleet: (0..n_clients)
            .map(|i| DeviceSnap {
                name: format!("dev{i}"),
                capability: rng.f64(),
                bandwidth_mbps: 1.0 + rng.f64() * 100.0,
                latency_s: rng.f64() * 0.1,
                cores: 1 + rng.below(8) as u32,
                precision: if rng.below(2) == 0 { Precision::F32 } else { Precision::Int8 },
            })
            .collect(),
        clock_now: rng.f64() * 100.0,
        in_flight: (0..rng.below(3))
            .map(|s| Completion {
                at: 1000.0 + rng.f64(),
                round: rng.below(100) as usize,
                seq: s as usize,
                client: rng.below(n_clients as u64) as usize,
            })
            .collect(),
        pending: (0..rng.below(2))
            .map(|s| PendingSnap {
                round: rng.below(100),
                seq: s,
                client: rng.below(n_clients as u64) as u32,
                weight: rng.f64() * 100.0,
                params: init_params(spec, rng.next_u64()),
                skeleton: vec![(0..rng.below(4)).map(|_| rng.below(8) as i32).collect()],
                delta: if rng.below(2) == 0 {
                    None
                } else {
                    Some(WirePayload::Full(init_params(spec, rng.next_u64())))
                },
            })
            .collect(),
        anchors: (0..n_clients)
            .map(|_| {
                if rng.below(2) == 0 {
                    None
                } else {
                    Some(init_params(spec, rng.next_u64()))
                }
            })
            .collect(),
        ledger: CommLedger {
            upload_params: rng.next_u64() >> 32,
            download_params: rng.next_u64() >> 32,
            upload_wire_bytes: rng.next_u64() >> 32,
            download_wire_bytes: rng.next_u64() >> 32,
            wasted_wire_bytes: rng.next_u64() >> 32,
            upload_raw_bytes: rng.next_u64() >> 32,
            download_raw_bytes: rng.next_u64() >> 32,
            rounds: rng.below(1000),
        },
        rounds_log: (0..rng.below(4))
            .map(|r| RoundLog {
                round: r as usize,
                phase: "updateskel".into(),
                mean_loss: rng.f64() * 3.0,
                new_acc: if rng.below(2) == 0 { None } else { Some(rng.f64()) },
                local_acc: if rng.below(2) == 0 { None } else { Some(rng.f64()) },
                comm_params: rng.next_u64() >> 40,
                comm_wire_bytes: rng.next_u64() >> 40,
                sim_round_secs: rng.f64() * 10.0,
                client_secs: (0..n_clients).map(|c| (c, rng.f64())).collect(),
                dropped: rng.below(3) as usize,
                stale: rng.below(3) as usize,
                wall_secs: rng.f64(),
            })
            .collect(),
    }
}

#[test]
fn arbitrary_snapshots_round_trip_bitwise() {
    let spec = toy_spec();
    for seed in 0..25u64 {
        let snap = arbitrary_snapshot(&spec, 0xC0FFEE ^ (seed.wrapping_mul(0x9E3779B97F4A7C15)));
        let bytes = snap.encode();
        let back = Snapshot::decode(&spec, &bytes).expect("round-trip decode");
        // struct equality is bitwise here: NaN losses travel as bit
        // patterns and every float field was generated finite
        assert_eq!(back, snap, "seed {seed}");
        assert_eq!(back.encode(), bytes, "seed {seed}: re-encode not canonical");
    }
}

#[test]
fn empty_and_ragged_residuals_survive() {
    let spec = toy_spec();
    let mut snap = arbitrary_snapshot(&spec, 7);
    snap.clients[0].ef_residual = vec![];
    if snap.clients.len() > 1 {
        snap.clients[1].ef_residual = vec![vec![], vec![-0.0, f32::MIN_POSITIVE], vec![]];
    }
    let back = Snapshot::decode(&spec, &snap.encode()).unwrap();
    assert_eq!(back, snap);
    if snap.clients.len() > 1 {
        // -0.0 keeps its sign bit (bitwise, not just ==)
        assert_eq!(back.clients[1].ef_residual[1][0].to_bits(), (-0.0f32).to_bits());
    }
}

#[test]
fn every_strict_prefix_is_a_typed_error() {
    let spec = toy_spec();
    let bytes = arbitrary_snapshot(&spec, 42).encode();
    for cut in 0..bytes.len() {
        match Snapshot::decode(&spec, &bytes[..cut]) {
            Ok(_) => panic!("prefix of {cut}/{} bytes decoded successfully", bytes.len()),
            Err(SnapshotError::Truncated)
            | Err(SnapshotError::ChecksumMismatch { .. })
            | Err(SnapshotError::Malformed(_))
            | Err(SnapshotError::MissingSection(_)) => {}
            Err(other) => panic!("prefix at {cut}: unexpected error kind {other}"),
        }
    }
}

#[test]
fn every_single_byte_flip_is_a_typed_error() {
    let spec = toy_spec();
    let bytes = arbitrary_snapshot(&spec, 99).encode();
    // flipping any one byte must be caught (almost always by the
    // checksum; magic/version flips by their own checks) — and must
    // never panic or decode
    for i in 0..bytes.len() {
        let mut corrupt = bytes.clone();
        corrupt[i] ^= 0xA5;
        assert!(
            Snapshot::decode(&spec, &corrupt).is_err(),
            "flip at byte {i}/{} decoded successfully",
            bytes.len()
        );
    }
}

#[test]
fn version_bump_is_rejected_with_both_versions_named() {
    let spec = toy_spec();
    let mut bytes = arbitrary_snapshot(&spec, 3).encode();
    // patch the u16 LE version after the 8-byte magic, then re-sign the
    // trailing checksum so only the version differs
    bytes[8] = VERSION as u8 + 1;
    let n = bytes.len();
    let sum = wire::fnv1a32(&bytes[..n - 4]);
    bytes[n - 4..].copy_from_slice(&sum.to_le_bytes());
    match Snapshot::decode(&spec, &bytes).unwrap_err() {
        SnapshotError::UnsupportedVersion { found, supported } => {
            assert_eq!(found, VERSION + 1);
            assert_eq!(supported, VERSION);
        }
        other => panic!("expected UnsupportedVersion, got {other}"),
    }
}

#[test]
fn unknown_trailing_section_is_rejected_not_skipped() {
    let spec = toy_spec();
    let snap = arbitrary_snapshot(&spec, 11);
    let bytes = snap.encode();
    // splice an unknown (tag, len, body) section before the checksum and
    // re-sign — a well-formed file from some future writer
    let mut patched = bytes[..bytes.len() - 4].to_vec();
    patched.extend_from_slice(&0x00EEu16.to_le_bytes());
    patched.extend_from_slice(&4u32.to_le_bytes());
    patched.extend_from_slice(&[9, 9, 9, 9]);
    let sum = wire::fnv1a32(&patched);
    patched.extend_from_slice(&sum.to_le_bytes());
    // the revision policy: unknown state is never silently dropped
    assert_eq!(
        Snapshot::decode(&spec, &patched).unwrap_err(),
        SnapshotError::UnknownSection(0x00EE)
    );
}

#[test]
fn snapshot_errors_downcast_through_anyhow() {
    let spec = toy_spec();
    let dir = std::env::temp_dir().join(format!("fedskel_snapfmt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("garbage.fsnap");
    std::fs::write(&path, b"not a snapshot at all").unwrap();
    let err = Snapshot::load(&spec, &path).unwrap_err();
    assert_eq!(err.downcast_ref::<SnapshotError>(), Some(&SnapshotError::BadMagic));
}

#[test]
fn global_params_round_trip_through_the_wire_codec_bitwise() {
    // the GLOBAL section reuses the transport codec's F32 Full framing;
    // pin that adversarial bit patterns survive it inside a snapshot
    let spec = toy_spec();
    let mut snap = arbitrary_snapshot(&spec, 21);
    let patterns = [0.0f32, -0.0, 1e-38, f32::MIN_POSITIVE, 3.141_592_7, -1e38];
    let mut global: Params = init_params(&spec, 1);
    {
        let d = global[0].data_mut();
        for (i, &p) in patterns.iter().enumerate() {
            if i < d.len() {
                d[i] = p;
            }
        }
    }
    snap.global = global;
    let back = Snapshot::decode(&spec, &snap.encode()).unwrap();
    for (a, b) in back.global.iter().zip(&snap.global) {
        for (x, y) in a.data().iter().zip(b.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
