//! Compression pipeline over the full coordinator.
//!
//! The load-bearing contracts:
//!
//! * **Identity regression** — `--compress identity` (the default) is
//!   the pre-compression wire path bit for bit: same frames, same FNV
//!   param digests, same byte totals as a config that never heard of
//!   compression. This is what keeps the PR-4 golden digests valid.
//! * **Delta-down losslessness** — `--delta-down` re-encodes full
//!   downloads against each client's anchor but reconstructs the
//!   identical model, so training results are bitwise unchanged.
//! * **Thread-count determinism** — encode → decode → error-feedback
//!   round-trips are pure functions of the update values, so a
//!   compressed run's digest (the FNV harness) is identical at any
//!   kernel thread budget.
//! * **Error feedback is bounded** — with EF the cumulative decoded
//!   update tracks the true cumulative update to within one step's
//!   quantization error; without it the error compounds.

use fedskel::compress::{block_roundtrip, CompressKind, Compressor, Residual};
use fedskel::config::{Method, RunConfig};
use fedskel::coordinator::Coordinator;
use fedskel::model::params_digest;
use fedskel::runtime::mock::MockBackend;
use fedskel::runtime::NativeBackend;

fn mock_cfg(method: Method) -> RunConfig {
    RunConfig {
        method,
        model: "toy".into(),
        num_clients: 4,
        shards_per_client: 2,
        dataset_size: 400,
        new_test_size: 64,
        rounds: 8,
        local_steps: 2,
        updateskel_per_setskel: 3,
        eval_every: 0,
        ..RunConfig::default()
    }
}

fn run_mock(cfg: RunConfig) -> Coordinator<MockBackend> {
    let mut c = Coordinator::new(cfg, MockBackend::toy()).unwrap();
    c.run().unwrap();
    c
}

#[test]
fn identity_compression_is_bitwise_the_pre_compress_wire_path() {
    for method in [Method::FedSkel, Method::FedAvg, Method::LgFedAvg, Method::FedMtl] {
        // the config that never heard of compression
        let plain = run_mock(mock_cfg(method));
        // identity compression spelled out loud — including flags that
        // only matter under a real compressor, which identity must
        // ignore by never entering the delta pipeline
        let mut icfg = mock_cfg(method);
        icfg.compress = CompressKind::Identity;
        icfg.error_feedback = true;
        let ident = run_mock(icfg);
        assert_eq!(
            params_digest(&plain.global),
            params_digest(&ident.global),
            "{method:?}: identity compression changed the trained model"
        );
        assert_eq!(plain.global, ident.global, "{method:?}");
        assert_eq!(
            plain.ledger.total_wire_bytes(),
            ident.ledger.total_wire_bytes(),
            "{method:?}: identity compression changed the frame bytes"
        );
        assert_eq!(plain.ledger.total_params(), ident.ledger.total_params());
        // error feedback under identity leaves no residual state behind
        assert!(ident.clients.iter().all(|cl| cl.ef_residual.is_empty()), "{method:?}");
    }
}

#[test]
fn delta_down_is_lossless_for_every_full_download_method() {
    // f32 and f16 are elementwise codecs, so a delta-down download
    // delivers bitwise what a plain download would — at both quants.
    // (int8's per-block scale would break this; the config rejects it.)
    for quant in [fedskel::transport::wire::Quant::F32, fedskel::transport::wire::Quant::F16] {
        for method in [Method::FedSkel, Method::FedAvg, Method::FedMtl] {
            let mut pcfg = mock_cfg(method);
            pcfg.quant = quant;
            let plain = run_mock(pcfg);
            let mut dcfg = mock_cfg(method);
            dcfg.quant = quant;
            dcfg.delta_down = true;
            let delta = run_mock(dcfg);
            assert_eq!(
                params_digest(&plain.global),
                params_digest(&delta.global),
                "{method:?}/{quant:?}: delta-down must be lossless"
            );
            assert_eq!(plain.ledger.total_params(), delta.ledger.total_params(), "{method:?}");
            // the raw-f32 accounting covers the same exchanges either way
            assert_eq!(
                plain.ledger.total_raw_bytes(),
                delta.ledger.total_raw_bytes(),
                "{method:?}"
            );
        }
    }
}

#[test]
fn delta_down_rejects_int8_quant() {
    let mut cfg = mock_cfg(Method::FedAvg);
    cfg.quant = fedskel::transport::wire::Quant::Int8;
    cfg.delta_down = true;
    let err = format!("{:#}", cfg.validate().unwrap_err());
    assert!(err.contains("delta_down"), "{err}");
    // int8 without delta-down, and delta-down without int8, stay legal
    let mut cfg = mock_cfg(Method::FedAvg);
    cfg.quant = fedskel::transport::wire::Quant::Int8;
    assert!(cfg.validate().is_ok());
    let mut cfg = mock_cfg(Method::FedAvg);
    cfg.delta_down = true;
    assert!(cfg.validate().is_ok());
}

#[test]
fn deadline_drops_fold_discarded_updates_back_into_residuals() {
    // a compressed update the deadline policy discards must not vanish:
    // its decoded content returns to the client's error-feedback
    // residual, so the next upload re-carries it. The mock fleet's
    // slowest device (capability 1/8, ~1.28 s rounds) misses a 1.0 s
    // deadline every round.
    let mut cfg = mock_cfg(Method::FedAvg);
    cfg.sched = fedskel::sched::SchedKind::DeadlineDrop;
    cfg.deadline_secs = 1.0;
    cfg.compress = CompressKind::TopK;
    cfg.topk_ratio = 0.25;
    cfg.error_feedback = true;
    let c = run_mock(cfg);
    let dropped: usize = c.log.rounds.iter().map(|r| r.dropped).sum();
    assert!(dropped > 0, "the straggler must miss the deadline");
    assert!(c.ledger.wasted_wire_bytes > 0);
    // the always-dropped straggler still carries residual state, and it
    // reflects whole discarded updates (nonzero somewhere)
    let straggler = &c.clients[0];
    assert!(!straggler.ef_residual.is_empty());
    let nonzero = straggler.ef_residual.iter().flatten().any(|&v| v != 0.0);
    assert!(nonzero, "discarded updates must land in the residual");
    for t in &c.global {
        assert!(t.data().iter().all(|v| v.is_finite()));
    }
}

#[test]
fn compressed_runs_change_bits_but_stay_finite_and_cheaper() {
    // the toy model's tensors all sit below QUANT_MIN_NUMEL (where the
    // quantizers deliberately stay f32), so the lossy compressor that
    // bites at this scale is top-k
    let plain = run_mock(mock_cfg(Method::FedAvg));
    let mut ccfg = mock_cfg(Method::FedAvg);
    ccfg.compress = CompressKind::TopK;
    ccfg.topk_ratio = 0.25;
    ccfg.error_feedback = true;
    ccfg.delta_down = true;
    let comp = run_mock(ccfg);
    // top-k deltas genuinely drop values — the digests must differ…
    assert_ne!(params_digest(&plain.global), params_digest(&comp.global));
    // …while error feedback keeps the model trainable and finite
    for t in &comp.global {
        assert!(t.data().iter().all(|v| v.is_finite()));
    }
    assert!(comp.log.rounds.iter().all(|r| r.mean_loss.is_finite()));
    // fewer bytes for the same logical traffic
    assert!(comp.ledger.total_wire_bytes() < plain.ledger.total_wire_bytes());
    assert_eq!(comp.ledger.total_params(), plain.ledger.total_params());
    assert!(comp.ledger.compression_ratio() > 1.0);
}

#[test]
fn compressed_ef_run_is_deterministic_across_thread_counts() {
    // the FNV digest harness, over real native compute: an int8 +
    // error-feedback + delta-down run must produce the same trained
    // model at any kernel thread budget (and on every rerun).
    let native_cfg = |threads: usize| RunConfig {
        method: Method::FedSkel,
        model: "tiny_native".into(),
        num_clients: 4,
        shards_per_client: 2,
        dataset_size: 240,
        new_test_size: 32,
        rounds: 4,
        local_steps: 2,
        updateskel_per_setskel: 3,
        eval_every: 0,
        seed: 7,
        threads,
        compress: CompressKind::Int8,
        error_feedback: true,
        delta_down: true,
        ..RunConfig::default()
    };
    let run = |threads: usize| {
        let mut c = Coordinator::new(native_cfg(threads), NativeBackend::tiny()).unwrap();
        c.run().unwrap();
        (params_digest(&c.global), c.ledger.total_wire_bytes())
    };
    let (d1, b1) = run(1);
    let (d1b, b1b) = run(1);
    assert_eq!(d1, d1b, "same-config rerun must be bitwise identical");
    assert_eq!(b1, b1b);
    let (d2, b2) = run(2);
    assert_eq!(d1, d2, "digest diverged between 1 and 2 kernel threads");
    assert_eq!(b1, b2, "wire bytes diverged between 1 and 2 kernel threads");
}

#[test]
fn error_feedback_bounds_cumulative_quantization_error() {
    // feed the same update through the int8 codec 20 times: with EF the
    // cumulative decoded sum tracks the true sum to within one step's
    // quantization error; without EF the bias compounds every round.
    let comp = CompressKind::Int8.build(0.1);
    let n = 128; // ≥ QUANT_MIN_NUMEL so the plan really is int8
    let v: Vec<f32> = (0..n).map(|i| (i as f32) * 0.013 - 0.77).collect();
    let rounds = 20usize;

    let mut residual = vec![0.0f32; n];
    let mut sum_ef = vec![0.0f64; n];
    let mut sum_noef = vec![0.0f64; n];
    let mut max_step_err = 0.0f32;
    for _ in 0..rounds {
        // error feedback: compress (v + residual), carry the miss forward
        let adjusted: Vec<f32> = v.iter().zip(&residual).map(|(a, r)| a + r).collect();
        let plan = comp.plan(&adjusted);
        let decoded = block_roundtrip(&adjusted, &plan);
        for j in 0..n {
            residual[j] = adjusted[j] - decoded[j];
            sum_ef[j] += decoded[j] as f64;
            max_step_err = max_step_err.max(residual[j].abs());
        }
        // no feedback: the same miss lands every round
        let plan = comp.plan(&v);
        let decoded = block_roundtrip(&v, &plan);
        for j in 0..n {
            sum_noef[j] += decoded[j] as f64;
        }
    }
    let true_sum: Vec<f64> = v.iter().map(|&x| x as f64 * rounds as f64).collect();
    let err_ef: f64 = sum_ef.iter().zip(&true_sum).map(|(a, b)| (a - b).abs()).sum();
    let err_noef: f64 = sum_noef.iter().zip(&true_sum).map(|(a, b)| (a - b).abs()).sum();
    // EF: the only outstanding error is the last residual, one step's worth
    let per_coord_bound = (max_step_err as f64) + 1e-6;
    for (a, b) in sum_ef.iter().zip(&true_sum) {
        assert!((a - b).abs() <= per_coord_bound, "EF error {} > {per_coord_bound}", (a - b).abs());
    }
    assert!(
        err_ef < err_noef,
        "error feedback must beat fire-and-forget: {err_ef} !< {err_noef}"
    );
}

#[test]
fn compression_composes_with_async_scheduling() {
    // stale arrivals compress and reconstruct against their own origin
    // anchor (encode/decode happens at submission time), so a buffered
    // async run with compression must stay finite and keep deferring
    // stragglers exactly like the uncompressed one.
    let mut acfg = mock_cfg(Method::FedSkel);
    acfg.sched = fedskel::sched::SchedKind::AsyncBuffer;
    acfg.buffer_k = 3; // of 4 participants
    acfg.staleness_alpha = 0.5;
    acfg.rounds = 10;
    acfg.compress = CompressKind::Int8;
    acfg.error_feedback = true;
    acfg.delta_down = true;
    let c = run_mock(acfg);
    assert_eq!(c.log.rounds.len(), 10);
    let stale: usize = c.log.rounds.iter().map(|r| r.stale).sum();
    assert!(stale > 0, "buffered run never deferred an update");
    assert!(c.log.rounds.iter().all(|r| r.mean_loss.is_finite()));
    for t in &c.global {
        assert!(t.data().iter().all(|v| v.is_finite()));
    }
}

#[test]
fn unknown_compress_flag_value_lists_valid_options() {
    // the CLI small-fix: both --quant and --compress errors enumerate
    // their modes the same way
    let err = format!("{:#}", CompressKind::parse("lz4").unwrap_err());
    assert!(err.contains("identity|f16|int8|topk"), "{err}");
    let err = format!("{:#}", fedskel::transport::wire::Quant::parse("bf16").unwrap_err());
    assert!(err.contains("f32|f16|int8"), "{err}");
}

#[test]
fn residual_type_is_reusable_outside_the_coordinator() {
    // Residual is public API: external harnesses can drive the EF loop
    let comp = CompressKind::TopK.build(0.5);
    let mut res: Residual = Vec::new();
    let spec = fedskel::runtime::mock::toy_spec();
    let anchor = fedskel::model::init_params(&spec, 1);
    let trained = fedskel::model::init_params(&spec, 2);
    let (_payload, plans) = fedskel::compress::compress_update(
        comp.as_ref(),
        &spec,
        &fedskel::comm::ExchangeKind::Full,
        &[],
        &anchor,
        &trained,
        Some(&mut res),
    )
    .unwrap();
    assert_eq!(plans.len(), spec.params.len());
    assert_eq!(res.len(), spec.params.len());
}
