//! Scheduler parity + policy behavior over the full coordinator.
//!
//! The load-bearing contract: the virtual-clock refactor changed *how*
//! rounds are driven, never *what* they compute — `Sync` reproduces the
//! pre-scheduler barrier loop bit-for-bit, and the two other policies
//! reduce to it in their degenerate configurations:
//!
//! * `DeadlineDrop` with an infinite deadline ≡ `Sync`;
//! * `AsyncBuffer` with `K = participants` (`buffer_k = 0`) and zero
//!   staleness discount ≡ `Sync`.
//!
//! Plus the behavioral tests for the non-degenerate configurations
//! (deadline drops shorten rounds and waste bytes; async buffering
//! defers stragglers with staleness) and the staleness-weight property
//! test.

use fedskel::config::{Method, RunConfig};
use fedskel::coordinator::Coordinator;
use fedskel::model::params_digest;
use fedskel::runtime::mock::MockBackend;
use fedskel::sched::{staleness_weight, SchedKind};

fn cfg(method: Method, sched: SchedKind) -> RunConfig {
    RunConfig {
        method,
        model: "toy".into(),
        num_clients: 5,
        shards_per_client: 2,
        dataset_size: 500,
        new_test_size: 64,
        rounds: 8,
        local_steps: 2,
        updateskel_per_setskel: 3,
        eval_every: 0,
        sched,
        ..RunConfig::default()
    }
}

fn run(cfg: RunConfig) -> Coordinator<MockBackend> {
    let mut c = Coordinator::new(cfg, MockBackend::toy()).unwrap();
    c.run().unwrap();
    c
}

#[test]
fn degenerate_policies_are_bitwise_sync() {
    for method in [Method::FedSkel, Method::FedAvg, Method::LgFedAvg, Method::FedMtl] {
        let sync = run(cfg(method, SchedKind::Sync));

        let mut dcfg = cfg(method, SchedKind::DeadlineDrop);
        dcfg.deadline_secs = f64::INFINITY;
        let deadline = run(dcfg);

        let mut acfg = cfg(method, SchedKind::AsyncBuffer);
        acfg.buffer_k = 0; // = all of this round's participants
        acfg.staleness_alpha = 0.0;
        let async_buf = run(acfg);

        // bitwise: same FNV digest, same tensors
        assert_eq!(
            params_digest(&sync.global),
            params_digest(&deadline.global),
            "{method:?}: deadline(inf) digest diverged from sync"
        );
        assert_eq!(
            params_digest(&sync.global),
            params_digest(&async_buf.global),
            "{method:?}: async(K=all, alpha=0) digest diverged from sync"
        );
        assert_eq!(sync.global, deadline.global, "{method:?}");
        assert_eq!(sync.global, async_buf.global, "{method:?}");
        // same traffic, nothing wasted, nothing dropped or stale
        for c in [&deadline, &async_buf] {
            assert_eq!(sync.ledger.total_wire_bytes(), c.ledger.total_wire_bytes());
            assert_eq!(c.ledger.wasted_wire_bytes, 0);
            assert!(c.log.rounds.iter().all(|r| r.dropped == 0 && r.stale == 0));
        }
        // identical virtual round times too
        for (a, b) in sync.log.rounds.iter().zip(&deadline.log.rounds) {
            assert!((a.sim_round_secs - b.sim_round_secs).abs() < 1e-12, "{method:?}");
        }
    }
}

#[test]
fn deadline_inf_matches_sync_even_under_partial_participation() {
    // over-selection only kicks in when the deadline can actually drop
    // someone; with an infinite deadline the selection (and therefore
    // the whole run) must stay bitwise sync at any participation.
    let mut scfg = cfg(Method::FedSkel, SchedKind::Sync);
    scfg.participation = 0.6;
    let sync = run(scfg);
    let mut dcfg = cfg(Method::FedSkel, SchedKind::DeadlineDrop);
    dcfg.participation = 0.6;
    dcfg.deadline_secs = f64::INFINITY;
    let deadline = run(dcfg);
    assert_eq!(params_digest(&sync.global), params_digest(&deadline.global));
    assert_eq!(sync.ledger.total_wire_bytes(), deadline.ledger.total_wire_bytes());
}

#[test]
fn async_fedskel_aggregates_stale_updates_by_their_own_skeleton() {
    // Non-degenerate async + FedSkel: skeleton-sparse UpdateSkel
    // arrivals defer into later rounds (including SetSkel ones), where
    // they must aggregate partially under their own recorded skeleton.
    let mut acfg = cfg(Method::FedSkel, SchedKind::AsyncBuffer);
    acfg.buffer_k = 4; // of 5 participants
    acfg.staleness_alpha = 0.5;
    acfg.rounds = 12;
    let c = run(acfg);
    assert_eq!(c.log.rounds.len(), 12);
    let stale: usize = c.log.rounds.iter().map(|r| r.stale).sum();
    assert!(stale > 0, "buffered FedSkel run never deferred an update");
    assert!(c.log.rounds.iter().all(|r| r.dropped == 0));
    assert!(c.log.rounds.iter().all(|r| r.mean_loss.is_finite()));
    // the global model stayed usable (no NaNs from mixed aggregation)
    for t in &c.global {
        assert!(t.data().iter().all(|v| v.is_finite()));
    }
}

#[test]
fn staleness_weights_properties() {
    for &alpha in &[0.0, 0.25, 0.5, 1.0, 2.5] {
        let mut prev = f64::INFINITY;
        for s in 0..60usize {
            let w = staleness_weight(s, alpha);
            assert!(w > 0.0 && w <= 1.0, "alpha {alpha} s {s}: w {w} outside (0, 1]");
            assert!(w <= prev, "alpha {alpha} s {s}: weight increased ({prev} -> {w})");
            prev = w;
            if s == 0 {
                assert_eq!(w, 1.0, "zero staleness must not be discounted");
            }
            if alpha == 0.0 {
                assert_eq!(w, 1.0, "alpha 0 disables the discount");
            }
        }
    }
}

#[test]
fn deadline_drops_stragglers_shortens_rounds_and_wastes_bytes() {
    let sync = run(cfg(Method::FedAvg, SchedKind::Sync));
    // the mock's r100 batch takes 0.08 s; the slowest device (capability
    // 1/8) needs 2 × 0.08 × 8 = 1.28 s/round, the next one ~0.47 s — a
    // 1.0 s deadline drops exactly the straggler every round.
    let mut dcfg = cfg(Method::FedAvg, SchedKind::DeadlineDrop);
    dcfg.deadline_secs = 1.0;
    let deadline = run(dcfg);

    let sync_total: f64 = sync.log.rounds.iter().map(|r| r.sim_round_secs).sum();
    let dl_total: f64 = deadline.log.rounds.iter().map(|r| r.sim_round_secs).sum();
    assert!(dl_total < sync_total, "deadline {dl_total} !< sync {sync_total}");
    assert!(deadline.log.rounds.iter().all(|r| r.dropped == 1), "straggler dropped each round");
    assert!(deadline.log.rounds.iter().all(|r| (r.sim_round_secs - 1.0).abs() < 1e-9));
    // the dropped client's frames were spent but never aggregated
    assert!(deadline.ledger.wasted_wire_bytes > 0);
    assert!(deadline.ledger.total_wire_bytes() < sync.ledger.total_wire_bytes());
    // dropping a contributor changes the trained model
    assert_ne!(params_digest(&sync.global), params_digest(&deadline.global));
}

#[test]
fn async_buffer_defers_stragglers_and_discounts_staleness() {
    let mut acfg = cfg(Method::FedAvg, SchedKind::AsyncBuffer);
    acfg.buffer_k = 4; // of 5 participants
    acfg.staleness_alpha = 0.5;
    acfg.rounds = 10;
    let async_buf = run(acfg);

    let mut scfg = cfg(Method::FedAvg, SchedKind::Sync);
    scfg.rounds = 10;
    let sync = run(scfg);

    assert_eq!(async_buf.log.rounds.len(), 10);
    // stragglers landed late at least once, nothing was ever discarded
    let stale: usize = async_buf.log.rounds.iter().map(|r| r.stale).sum();
    assert!(stale > 0, "no stale arrival in 10 buffered rounds");
    assert!(async_buf.log.rounds.iter().all(|r| r.dropped == 0));
    assert_eq!(async_buf.ledger.wasted_wire_bytes, 0);
    // closing rounds on the 4th arrival beats waiting for the 5th
    let a_total: f64 = async_buf.log.rounds.iter().map(|r| r.sim_round_secs).sum();
    let s_total: f64 = sync.log.rounds.iter().map(|r| r.sim_round_secs).sum();
    assert!(a_total < s_total, "async {a_total} !< sync {s_total}");
    // a busy client sits out the next round's sampling
    assert!(async_buf.log.rounds.iter().any(|r| r.client_secs.len() < 5));
}

#[test]
fn csv_and_json_carry_the_scheduler_columns() {
    let mut dcfg = cfg(Method::FedAvg, SchedKind::DeadlineDrop);
    dcfg.deadline_secs = 1.0;
    let c = run(dcfg);
    let csv = c.log.to_csv();
    let header = csv.lines().next().unwrap();
    assert!(header.contains("dropped,stale,client_secs"), "{header}");
    // every data row carries a non-empty per-client distribution cell
    for line in csv.lines().skip(1) {
        assert!(line.contains(':'), "no client_secs cell in {line}");
    }
    let json = c.log.to_json().to_string();
    assert!(json.contains("\"client_secs\""), "{json}");
    assert!(json.contains("\"dropped\":1"), "{json}");
}
