//! Multi-process end-to-end: real `fedskel serve` / `fedskel client`
//! binaries over real TCP sockets must reproduce the in-process
//! `fedskel train` param digest bitwise — through async scheduling,
//! injected transport faults, and a SIGKILLed coordinator resuming from
//! its checkpoint. The quick sync-parity test always runs; the longer
//! scenarios are `#[ignore]` and run in CI's `multiprocess-smoke` job
//! (`cargo test --release --test e2e_multiprocess -- --include-ignored`).

mod e2e;

use std::time::Duration;

use e2e::{digest, free_port, listen_addr, train_digest, wait_for_file, Proc, ScratchDir};

/// The canonical small native LeNet run (same shape as the CI digest
/// gates), shared verbatim between `train` and `serve` so the only
/// difference is where local training executes.
const RUN: &[&str] = &[
    "--clients",
    "3",
    "--rounds",
    "2",
    "--dataset-size",
    "240",
    "--new-test-size",
    "32",
    "--local-steps",
    "2",
    "--eval-every",
    "0",
    "--seed",
    "7",
    "--threads",
    "1",
    "--quiet",
];

fn serve_args<'a>(run: &[&'a str], extra: &[&'a str]) -> Vec<&'a str> {
    let mut v = vec!["serve"];
    v.extend_from_slice(run);
    v.extend_from_slice(&["--min-clients", "2", "--join-timeout-secs", "60"]);
    v.extend_from_slice(extra);
    v
}

fn client_args<'a>(addr: &'a str, id: &'a str) -> Vec<&'a str> {
    vec!["client", "--connect", addr, "--worker-id", id, "--quiet"]
}

/// Spawn serve + 2 worker processes, run `run` to completion, and
/// return the serve digest. Asserts every process exits cleanly — the
/// workers must see `Shutdown` (no orphans), the server must succeed.
fn serve_digest(run: &[&str], extra: &[&str]) -> String {
    let mut serve = Proc::spawn("serve", &serve_args(run, extra));
    let addr = listen_addr(&serve.expect_line("listening on "));
    let c1 = Proc::spawn("client-1", &client_args(&addr, "21"));
    let c2 = Proc::spawn("client-2", &client_args(&addr, "22"));
    let lines = serve.wait_success();
    c1.wait_success();
    c2.wait_success();
    digest(&lines)
}

/// Tentpole acceptance: a multi-process run over real sockets computes
/// the same model, bit for bit, as the in-process run.
#[test]
fn sync_multiprocess_digest_matches_in_process_train() {
    let golden = train_digest(RUN);
    let served = serve_digest(RUN, &["--listen", "127.0.0.1:0"]);
    assert_eq!(served, golden, "serve+clients must reproduce the in-process digest");
}

/// Same property under the async buffered scheduler. Batch seconds are
/// pinned so the virtual clock is a pure function of the config — the
/// precondition for cross-process digest comparison under any
/// time-sensitive policy (see `--fixed-batch-secs`).
#[test]
#[ignore = "multi-process async smoke — run with --ignored (CI multiprocess-smoke job)"]
fn async_multiprocess_digest_matches_in_process_train() {
    let mut run = RUN.to_vec();
    run.extend_from_slice(&[
        "--sched",
        "async",
        "--buffer-k",
        "2",
        "--staleness-alpha",
        "0.5",
        "--fixed-batch-secs",
        "0.08",
    ]);
    let golden = train_digest(&run);
    let served = serve_digest(&run, &["--listen", "127.0.0.1:0"]);
    assert_eq!(served, golden, "async serve+clients must reproduce the in-process digest");
}

/// Injected transport chaos on the server's data plane (drops, delays,
/// reorders, mid-frame truncation) must not perturb the digest — the
/// reliable-exchange loop recovers every casualty.
#[test]
#[ignore = "multi-process fault smoke — run with --ignored (CI multiprocess-smoke job)"]
fn faulted_serve_matches_the_clean_golden() {
    const FAULT: &str = "drop=0.1,delay=0.1,reorder=0.1,truncate=0.1,seed=11";
    let golden = train_digest(RUN);
    let served = serve_digest(RUN, &["--listen", "127.0.0.1:0", "--fault", FAULT]);
    assert_eq!(served, golden, "fault injection must be trajectory-neutral end to end");
}

/// Kill the coordinator with SIGKILL mid-run; restart it with
/// `--resume` on the same port. The stateless workers reconnect on
/// their own, and the resumed run's digest equals the uninterrupted
/// in-process run's.
#[test]
#[ignore = "multi-process crash-resume smoke — run with --ignored (CI multiprocess-smoke job)"]
fn sigkilled_serve_resumes_to_the_same_digest() {
    // heavier run so the coordinator is reliably still mid-run when the
    // second checkpoint lands and the SIGKILL arrives
    let run: &[&str] = &[
        "--clients",
        "4",
        "--rounds",
        "6",
        "--dataset-size",
        "960",
        "--new-test-size",
        "32",
        "--local-steps",
        "8",
        "--eval-every",
        "0",
        "--seed",
        "7",
        "--threads",
        "1",
        "--quiet",
    ];
    let golden = train_digest(run);

    let scratch = ScratchDir::new("sigkill_resume");
    let ckpt = scratch.path().join("ckpt");
    let ckpt_s = ckpt.to_str().unwrap().to_string();
    // a pre-picked port (not :0) so the restarted serve comes back on
    // the address the surviving workers are already retrying
    let addr = format!("127.0.0.1:{}", free_port());

    let mut serve1 = Proc::spawn(
        "serve-1",
        &serve_args(
            run,
            &["--listen", &addr, "--checkpoint-dir", &ckpt_s, "--checkpoint-every", "1"],
        ),
    );
    serve1.expect_line("listening on ");
    let mut args1 = client_args(&addr, "21");
    args1.extend_from_slice(&["--reconnect-secs", "120"]);
    let c1 = Proc::spawn("client-1", &args1);
    let mut args2 = client_args(&addr, "22");
    args2.extend_from_slice(&["--reconnect-secs", "120"]);
    let c2 = Proc::spawn("client-2", &args2);

    // snap_round_2 existing proves snap_round_1 is complete on disk —
    // resume from the *previous* checkpoint so a write interrupted by
    // the SIGKILL can never be the one we restore
    assert!(
        wait_for_file(&ckpt.join("snap_round_2.fsnap"), Duration::from_secs(120)),
        "no checkpoint appeared before the timeout"
    );
    serve1.kill();

    let resume = ckpt.join("snap_round_1.fsnap");
    let resume_s = resume.to_str().unwrap().to_string();
    let mut serve2 = Proc::spawn(
        "serve-2",
        &serve_args(
            run,
            &[
                "--listen",
                &addr,
                "--checkpoint-dir",
                &ckpt_s,
                "--checkpoint-every",
                "1",
                "--resume",
                &resume_s,
            ],
        ),
    );
    serve2.expect_line("listening on ");
    let lines = serve2.wait_success();
    assert_eq!(
        digest(&lines),
        golden,
        "the SIGKILL + resume run must reproduce the uninterrupted digest"
    );
    // the workers rode out the crash and exit cleanly on Shutdown
    c1.wait_success();
    c2.wait_success();
}
