//! Trace recording / replay parity over the full coordinator.
//!
//! The contract under test: a run is *event-sourced*, so a recorded
//! `trace.jsonl` replays into exactly the tables the live run produced
//! (CSV, JSON, ledger, registry — byte for byte), and attaching a sink
//! never perturbs the training itself (bitwise-identical param digests
//! with and without tracing).

use std::path::PathBuf;

use fedskel::config::{Method, RunConfig};
use fedskel::coordinator::Coordinator;
use fedskel::model::params_digest;
use fedskel::runtime::mock::MockBackend;
use fedskel::sched::SchedKind;
use fedskel::trace::{replay, watch, RingSink, RunEvent, TraceLevel};

fn cfg(sched: SchedKind) -> RunConfig {
    RunConfig {
        method: Method::FedSkel,
        model: "toy".into(),
        num_clients: 5,
        shards_per_client: 2,
        dataset_size: 500,
        new_test_size: 64,
        rounds: 8,
        local_steps: 2,
        updateskel_per_setskel: 3,
        eval_every: 4,
        sched,
        ..RunConfig::default()
    }
}

fn run(cfg: RunConfig) -> Coordinator<MockBackend> {
    let mut c = Coordinator::new(cfg, MockBackend::toy()).unwrap();
    c.run().unwrap();
    c
}

fn temp_trace(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fedskel_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn recorded_trace_replays_into_identical_tables() {
    // a deadline-drop run so the trace carries drops and wasted bytes too
    let path = temp_trace("deadline.jsonl");
    let mut c = cfg(SchedKind::DeadlineDrop);
    c.deadline_secs = 1.0;
    c.trace = Some(path.to_string_lossy().into_owned());
    let live = run(c);

    let r = replay::read_trace(&path).unwrap();
    assert!(r.events > 0);
    assert_eq!(r.version, fedskel::trace::TRACE_VERSION);

    // the three derived tables rebuild exactly from the event stream
    assert_eq!(r.folder.log.to_csv(), live.log.to_csv(), "per-round CSV diverged");
    assert_eq!(
        r.folder.log.to_json().to_string(),
        live.log.to_json().to_string(),
        "per-round JSON diverged"
    );
    assert_eq!(r.folder.ledger, live.ledger, "comm ledger diverged");
    assert_eq!(
        r.folder.registry.to_json().to_string(),
        live.registry.to_json().to_string(),
        "metrics registry diverged"
    );

    // the waste actually happened and survived the roundtrip
    assert!(live.ledger.wasted_wire_bytes > 0);
    assert_eq!(r.folder.ledger.wasted_wire_bytes, live.ledger.wasted_wire_bytes);
    std::fs::remove_file(&path).ok();
}

#[test]
fn tracing_leaves_the_trained_model_bit_identical() {
    let untraced = run(cfg(SchedKind::Sync));

    let path = temp_trace("sync.jsonl");
    let mut c = cfg(SchedKind::Sync);
    c.trace = Some(path.to_string_lossy().into_owned());
    let traced = run(c);

    assert_eq!(
        params_digest(&untraced.global),
        params_digest(&traced.global),
        "attaching a JsonlSink changed the trained model"
    );
    assert_eq!(untraced.global, traced.global);
    // the last round_close recorded that same digest as a hex string
    let text = std::fs::read_to_string(&path).unwrap();
    let hex = format!("{:#018x}", params_digest(&traced.global));
    assert!(text.contains(&hex), "trace is missing the final digest {hex}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn round_level_trace_still_reproduces_the_run_log() {
    let frame_path = temp_trace("frame.jsonl");
    let mut fc = cfg(SchedKind::Sync);
    fc.trace = Some(frame_path.to_string_lossy().into_owned());
    let live = run(fc);

    let round_path = temp_trace("round.jsonl");
    let mut rc = cfg(SchedKind::Sync);
    rc.trace = Some(round_path.to_string_lossy().into_owned());
    rc.trace_level = TraceLevel::Round;
    run(rc);

    let frame = replay::read_trace(&frame_path).unwrap();
    let round = replay::read_trace(&round_path).unwrap();
    // a coarse trace is smaller but the RunLog folds entirely from
    // round_close/eval, so the round tables still match the live run
    assert!(round.events < frame.events);
    assert_eq!(round.folder.log.to_csv(), live.log.to_csv());
    // the ledger, by contrast, needs frame-level exchange events
    assert_eq!(round.folder.ledger.total_wire_bytes(), 0);
    assert_eq!(frame.folder.ledger, live.ledger);
    std::fs::remove_file(&frame_path).ok();
    std::fs::remove_file(&round_path).ok();
}

#[test]
fn report_summary_and_watch_render_from_a_recording() {
    let path = temp_trace("report.jsonl");
    let mut c = cfg(SchedKind::DeadlineDrop);
    c.deadline_secs = 1.0;
    c.trace = Some(path.to_string_lossy().into_owned());
    run(c);

    let r = replay::read_trace(&path).unwrap();
    let summary = replay::summary_table(&r);
    assert!(summary.contains("wasted wire bytes"), "{summary}");
    assert!(summary.contains("fleet utilization"), "{summary}");
    assert!(summary.contains("compression ratio"), "{summary}");
    assert!(summary.contains("fedskel"), "{summary}");

    let dash = watch::render_file(&path).unwrap();
    assert!(dash.contains("fedskel watch"), "{dash}");
    assert!(dash.contains("accuracy"), "{dash}");
    assert!(dash.contains("wire"), "{dash}");
    assert!(dash.contains("utilized"), "{dash}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn ring_sink_buffers_the_stream_in_process() {
    let ring = RingSink::new(4096, TraceLevel::Frame);
    let handle = ring.handle();
    let mut coord = Coordinator::new(cfg(SchedKind::Sync), MockBackend::toy()).unwrap();
    coord.add_trace_sink(Box::new(ring));
    coord.run().unwrap();

    let events = handle.snapshot();
    assert!(!events.is_empty());
    assert!(matches!(events[0], RunEvent::RoundOpen { round: 0, .. }));
    let closes = events.iter().filter(|e| matches!(e, RunEvent::RoundClose { .. })).count();
    assert_eq!(closes, 8);
    // the buffered stream folds into the same tables the run produced
    let mut folder = fedskel::trace::fold::Folder::new();
    for ev in &events {
        folder.apply(ev);
    }
    assert_eq!(folder.log.to_csv(), coord.log.to_csv());
    assert_eq!(folder.ledger, coord.ledger);
}
