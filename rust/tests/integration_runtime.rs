//! Integration: real AOT artifacts through the PJRT runtime.
//!
//! These tests need `make artifacts` to have run (they skip otherwise so
//! `cargo test` stays green on a fresh checkout). They pin the
//! python→rust contract end-to-end: manifest loading, literal plumbing,
//! output slicing, skeleton-pruning semantics, and training-signal sanity.

#![cfg(feature = "pjrt")]

use fedskel::data::synthetic::{Dataset, DatasetKind};
use fedskel::model::{init_params, Manifest};
use fedskel::runtime::step::{Backend, PjrtBackend};
use fedskel::skeleton::identity_skeleton;

fn manifest() -> Option<Manifest> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Manifest::load(dir).expect("manifest loads"))
}

fn batch(spec: &fedskel::model::ModelSpec, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let kind = DatasetKind::Smnist;
    let data = Dataset::generate(kind, spec.train_batch * 4, seed);
    let numel = data.image_numel();
    let b = spec.train_batch;
    let mut x = vec![0.0f32; b * numel];
    let mut y = vec![0i32; b];
    for i in 0..b {
        data.copy_image(i, &mut x[i * numel..(i + 1) * numel]);
        y[i] = data.labels[i] as i32;
    }
    (x, y)
}

#[test]
fn train_step_runs_and_loss_is_sane() {
    let Some(man) = manifest() else { return };
    let mut backend = PjrtBackend::new(&man, "lenet_smnist").unwrap();
    let spec = backend.spec().clone();
    let params = init_params(&spec, 7);
    let (x, y) = batch(&spec, 1);
    let channels: Vec<usize> = spec.prunable.iter().map(|p| p.channels).collect();
    let skel = identity_skeleton(&channels);

    let out = backend
        .train_step(100, &params, &params, &x, &y, &skel, 0.05, 0.0)
        .unwrap();
    assert!(out.loss.is_finite());
    // CE of a 10-class random-init model starts near ln(10) ≈ 2.3
    assert!(out.loss > 0.5 && out.loss < 6.0, "loss {}", out.loss);
    assert_eq!(out.params.len(), spec.params.len());
    assert_eq!(out.importance.len(), spec.prunable.len());
    for (imp, p) in out.importance.iter().zip(&spec.prunable) {
        assert_eq!(imp.len(), p.channels);
        assert!(imp.iter().all(|&v| v >= 0.0), "importance is |A| ≥ 0");
    }
    // params actually moved
    let moved: f32 = out
        .params
        .iter()
        .zip(&params)
        .map(|(a, b)| a.sub(b).unwrap().max_abs())
        .fold(0.0, f32::max);
    assert!(moved > 0.0);
}

#[test]
fn pruned_step_touches_only_skeleton_channels() {
    let Some(man) = manifest() else { return };
    let mut backend = PjrtBackend::new(&man, "lenet_smnist").unwrap();
    let spec = backend.spec().clone();
    let params = init_params(&spec, 11);
    let (x, y) = batch(&spec, 2);

    // r=10 bucket on lenet: k = [1, 2, 12, 9]
    let ks = spec.train_artifact(10).unwrap().k.clone();
    let skel: Vec<Vec<i32>> = ks.iter().map(|&k| (0..k as i32).collect()).collect();
    let out = backend
        .train_step(10, &params, &params, &x, &y, &skel, 0.1, 0.0)
        .unwrap();

    // conv2 weight [5,5,6,16]: only the first 2 output channels change
    let pi = spec.prunable[1].weight_param;
    let d = out.params[pi].sub(&params[pi]).unwrap();
    let channels = spec.prunable[1].channels;
    let rows = d.len() / channels;
    for c in 0..channels {
        let col_sum: f32 = (0..rows).map(|r| d.data()[r * channels + c].abs()).sum();
        if (c as usize) < ks[1] {
            assert!(col_sum > 0.0, "skeleton channel {c} should train");
        } else {
            assert_eq!(col_sum, 0.0, "non-skeleton channel {c} must not change");
        }
    }
    // head (fc3) still trains
    let d_head = out.params[8].sub(&params[8]).unwrap();
    assert!(d_head.max_abs() > 0.0);
}

#[test]
fn identity_skeleton_matches_full_bucket() {
    let Some(man) = manifest() else { return };
    let mut backend = PjrtBackend::new(&man, "lenet_smnist").unwrap();
    let spec = backend.spec().clone();
    let params = init_params(&spec, 13);
    let (x, y) = batch(&spec, 3);
    let channels: Vec<usize> = spec.prunable.iter().map(|p| p.channels).collect();
    let skel = identity_skeleton(&channels);

    let o1 = backend.train_step(100, &params, &params, &x, &y, &skel, 0.05, 0.0).unwrap();
    let o2 = backend.train_step(100, &params, &params, &x, &y, &skel, 0.05, 0.0).unwrap();
    // determinism of the artifact
    assert_eq!(o1.loss, o2.loss);
    for (a, b) in o1.params.iter().zip(&o2.params) {
        assert_eq!(a.data(), b.data());
    }
}

#[test]
fn prox_term_changes_update() {
    let Some(man) = manifest() else { return };
    let mut backend = PjrtBackend::new(&man, "lenet_smnist").unwrap();
    let spec = backend.spec().clone();
    let params = init_params(&spec, 17);
    let mut far_global = params.clone();
    for t in far_global.iter_mut() {
        for v in t.data_mut() {
            *v += 1.0;
        }
    }
    let (x, y) = batch(&spec, 4);
    let channels: Vec<usize> = spec.prunable.iter().map(|p| p.channels).collect();
    let skel = identity_skeleton(&channels);

    let o0 = backend.train_step(100, &params, &far_global, &x, &y, &skel, 0.1, 0.0).unwrap();
    let o1 = backend.train_step(100, &params, &far_global, &x, &y, &skel, 0.1, 1.0).unwrap();
    // mu=1 pulls toward global: update differs by ≈ lr·mu·(g−p) = 0.1
    let d = o1.params[0].sub(&o0.params[0]).unwrap();
    let mean_shift = d.data().iter().sum::<f32>() / d.len() as f32;
    assert!((mean_shift - 0.1).abs() < 0.02, "mean prox shift {mean_shift}");
}

#[test]
fn eval_logits_shape_and_determinism() {
    let Some(man) = manifest() else { return };
    let mut backend = PjrtBackend::new(&man, "lenet_smnist").unwrap();
    let spec = backend.spec().clone();
    let params = init_params(&spec, 23);
    let numel: usize = spec.input_shape.iter().product();
    let x = vec![0.05f32; spec.eval_batch * numel];
    let l1 = backend.eval_logits(&params, &x).unwrap();
    let l2 = backend.eval_logits(&params, &x).unwrap();
    assert_eq!(l1.shape(), &[spec.eval_batch, spec.num_classes]);
    assert_eq!(l1.data(), l2.data());
}

#[test]
fn training_reduces_loss_on_fixed_batch() {
    let Some(man) = manifest() else { return };
    let mut backend = PjrtBackend::new(&man, "lenet_smnist").unwrap();
    let spec = backend.spec().clone();
    let mut params = init_params(&spec, 29);
    let (x, y) = batch(&spec, 5);
    let channels: Vec<usize> = spec.prunable.iter().map(|p| p.channels).collect();
    let skel = identity_skeleton(&channels);

    let mut losses = Vec::new();
    for _ in 0..8 {
        let out = backend.train_step(100, &params, &params, &x, &y, &skel, 0.1, 0.0).unwrap();
        params = out.params;
        losses.push(out.loss);
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.8),
        "loss did not decrease: {losses:?}"
    );
}

#[test]
fn pruned_training_also_reduces_loss() {
    let Some(man) = manifest() else { return };
    let mut backend = PjrtBackend::new(&man, "lenet_smnist").unwrap();
    let spec = backend.spec().clone();
    let mut params = init_params(&spec, 31);
    let (x, y) = batch(&spec, 6);
    let ks = spec.train_artifact(40).unwrap().k.clone();
    let skel: Vec<Vec<i32>> = ks.iter().map(|&k| (0..k as i32).collect()).collect();

    let mut losses = Vec::new();
    for _ in 0..8 {
        let out = backend.train_step(40, &params, &params, &x, &y, &skel, 0.1, 0.0).unwrap();
        params = out.params;
        losses.push(out.loss);
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "pruned loss did not decrease: {losses:?}"
    );
}

#[test]
fn batch_time_monotone_in_ratio() {
    let Some(man) = manifest() else { return };
    let mut backend = PjrtBackend::new(&man, "lenet_smnist").unwrap();
    backend.timing_reps = 3;
    let t10 = backend.batch_time_secs(10).unwrap();
    let t100 = backend.batch_time_secs(100).unwrap();
    assert!(t10 > 0.0 && t100 > 0.0);
    // pruned backprop must not be slower than full (allow 10% noise)
    assert!(t10 < t100 * 1.1, "t10 {t10} vs t100 {t100}");
}
