//! Fault-injection properties: the seeded chaos layer (`--fault`) may
//! drop, delay, reorder, or truncate any frame, and the coordinator's
//! reliable-exchange loop must absorb all of it — typed errors instead
//! of panics, retransmission instead of loss, stray-discard instead of
//! double aggregation — leaving the training trajectory bitwise
//! untouched and every casualty booked as wasted bytes.

use fedskel::config::{Method, RunConfig};
use fedskel::coordinator::Coordinator;
use fedskel::model::init_params;
use fedskel::runtime::mock::{toy_spec, MockBackend};
use fedskel::sched::SchedKind;
use fedskel::transport::fault::{FaultInjector, FaultPlan};
use fedskel::transport::wire::{self, Quant, RoundMsg, WirePayload};
use fedskel::transport::{Envelope, Loopback, Peer, Transport, TransportKind};

fn base_cfg(method: Method) -> RunConfig {
    RunConfig {
        method,
        model: "toy".into(),
        num_clients: 5,
        shards_per_client: 2,
        dataset_size: 500,
        new_test_size: 64,
        rounds: 6,
        local_steps: 2,
        updateskel_per_setskel: 2,
        eval_every: 0,
        transport: TransportKind::Loopback,
        ..RunConfig::default()
    }
}

fn run(cfg: RunConfig) -> Coordinator<MockBackend> {
    let mut c = Coordinator::new(cfg, MockBackend::toy()).unwrap();
    c.run().unwrap();
    c
}

/// Every truncation the injector produces decodes to a typed error —
/// the codec must never panic on a frame cut mid-body.
#[test]
fn truncated_frames_surface_typed_errors_never_panics() {
    let spec = toy_spec();
    let plan = FaultPlan::parse("truncate=1.0,seed=7").unwrap();
    let mut t = FaultInjector::new(Box::new(Loopback::new()), plan);
    let params = init_params(&spec, 3);
    let msg = RoundMsg { round: 2, client: 0, weight: 1.0, payload: WirePayload::full(&params) };
    let good = wire::encode(&msg, Quant::F32);

    let mut failures = 0;
    for _ in 0..32 {
        t.send(Envelope { from: Peer::Server, to: Peer::Client(0), frame: good.clone() })
            .unwrap();
        let env = t.recv(Peer::Client(0)).unwrap().expect("truncation delivers, never drops");
        assert!(env.frame.len() < good.len(), "the frame must actually be cut");
        // plain decode, anchored decode, and header peeking all refuse
        // the damage with errors (or None), never a panic
        assert!(wire::decode(&spec, &env.frame).is_err());
        assert!(wire::decode_frame(&spec, &env.frame, None).is_err());
        let _ = wire::peek_ids(&env.frame);
        failures += 1;
    }
    assert_eq!(failures, 32);
    assert_eq!(t.stats.truncated, 32);
}

/// The tentpole neutrality property, across every scheduler: a faulted
/// run's global model, useful wire bytes, and useful param counts are
/// bitwise identical to the clean run's — chaos only ever adds *wasted*
/// bytes. This is also the no-double-aggregation guarantee: duplicate
/// frames (a retransmit racing a delayed original) would perturb the
/// aggregate if one ever counted twice.
#[test]
fn fault_injection_is_trajectory_neutral_for_every_scheduler() {
    for (sched, buffer_k) in
        [(SchedKind::Sync, 0), (SchedKind::DeadlineDrop, 0), (SchedKind::AsyncBuffer, 3)]
    {
        let mk = || {
            let mut cfg = base_cfg(Method::FedSkel);
            cfg.sched = sched;
            cfg.buffer_k = buffer_k;
            cfg
        };
        let clean = run(mk());
        let mut faulted = mk();
        let plan = "drop=0.12,delay=0.1,reorder=0.1,truncate=0.08,seed=40";
        faulted.fault = Some(FaultPlan::parse(plan).unwrap());
        let faulty = run(faulted);

        let name = sched.name();
        assert_eq!(clean.global, faulty.global, "global params must match under {name}");
        assert_eq!(
            clean.ledger.total_wire_bytes(),
            faulty.ledger.total_wire_bytes(),
            "useful wire bytes must match under {name}"
        );
        assert_eq!(
            clean.ledger.total_params(),
            faulty.ledger.total_params(),
            "useful param accounting must match under {name} (double aggregation would inflate it)"
        );
        assert!(
            faulty.ledger.wasted_wire_bytes > clean.ledger.wasted_wire_bytes,
            "injected faults must surface as wasted bytes under {name}"
        );
    }
}

/// Drop-only chaos: every lost frame is retransmitted (the run
/// completes), ledgered as wasted bytes, and counted by the
/// `net/fault_retries` metric — loss is visible, never silent.
#[test]
fn dropped_frames_are_ledgered_and_counted_as_retries() {
    let mut cfg = base_cfg(Method::FedAvg);
    cfg.fault = Some(FaultPlan::parse("drop=0.25,seed=9").unwrap());
    let c = run(cfg);

    let retries = c.registry.counter("net/fault_retries");
    assert!(retries > 0, "a 25% drop rate over 6 rounds must force retries");
    assert!(c.ledger.wasted_wire_bytes > 0);
    assert_eq!(c.registry.counter("comm/wasted_wire_bytes"), c.ledger.wasted_wire_bytes);
    // and the trajectory still matches the clean run
    let clean = run(base_cfg(Method::FedAvg));
    assert_eq!(clean.global, c.global);
}

/// The injector composes over any inner transport and is deterministic
/// in its seed: same plan, same traffic, same casualties.
#[test]
fn fault_plan_seed_determinism() {
    let spec = toy_spec();
    let msg = RoundMsg {
        round: 0,
        client: 1,
        weight: 1.0,
        payload: WirePayload::full(&init_params(&spec, 1)),
    };
    let frame = wire::encode(&msg, Quant::F32);
    let observe = |seed: u64| {
        let plan = FaultPlan::parse(&format!("drop=0.3,truncate=0.2,seed={seed}")).unwrap();
        let mut t = FaultInjector::new(Box::new(Loopback::new()), plan);
        let mut pattern = Vec::new();
        for _ in 0..40 {
            t.send(Envelope { from: Peer::Server, to: Peer::Client(1), frame: frame.clone() })
                .unwrap();
            pattern.push(match t.recv(Peer::Client(1)).unwrap() {
                None => 0u8,
                Some(env) if env.frame.len() < frame.len() => 1,
                Some(_) => 2,
            });
        }
        pattern
    };
    assert_eq!(observe(5), observe(5), "same seed, same casualty pattern");
    assert_ne!(observe(5), observe(6), "different seeds must diverge");
}
