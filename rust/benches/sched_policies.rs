//! `cargo bench --bench sched_policies` — round-scheduler comparison.
//!
//! Full federated runs on the native backend (tiny spec, pinned batch
//! seconds) per (method × fleet skew × scheduling policy), reporting
//! makespan, time-to-accuracy, and straggler utilization, written to
//! `BENCH_sched.json` (`FEDSKEL_BENCH_OUT` overrides;
//! `FEDSKEL_BENCH_SMOKE=1` is the small CI profile;
//! `FEDSKEL_BENCH_ROUNDS` overrides the round count). The bench itself
//! asserts that the DeadlineDrop and AsyncBuffer makespans land strictly
//! below the Sync barrier's on every fleet — a failed assertion fails
//! the bench.

fn main() {
    match fedskel::bench::sched::run_env("BENCH_sched.json") {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("sched_policies: {e:#}");
            std::process::exit(1);
        }
    }
}
