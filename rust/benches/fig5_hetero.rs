//! `cargo bench --bench fig5_hetero` — regenerates paper Figure 5:
//! per-device one-batch runtime on an 8-device heterogeneous fleet,
//! FedSkel (r_i ∝ c_i) vs FedAvg.

#[cfg(feature = "pjrt")]
use fedskel::model::Manifest;

#[cfg(feature = "pjrt")]
fn main() {
    let dir = std::env::var("FEDSKEL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("fig5_hetero: skipping ({e:#}) — run `make artifacts`");
            return;
        }
    };
    match fedskel::bench::fig5::run(&manifest, 8, 5) {
        Ok(report) => println!("\n{report}"),
        Err(e) => {
            eprintln!("fig5_hetero failed: {e:#}");
            std::process::exit(1);
        }
    }
}

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!("fig5_hetero: built without the `pjrt` feature — artifact timing needs the PJRT runtime");
}
