//! `cargo bench --bench trace_overhead` — JsonlSink vs NullSink cost.
//!
//! Runs the same native-backend training job with a null trace sink and
//! with a full frame-level `trace.jsonl`, takes the minimum wall time
//! over its trials, and fails if the JSONL arm exceeds 5% overhead
//! (+20 ms slack) or if tracing perturbed the trained model. Report goes
//! to `BENCH_trace_overhead.json` (`FEDSKEL_BENCH_OUT` overrides;
//! `FEDSKEL_BENCH_SMOKE=1` is the small CI profile).

fn main() {
    match fedskel::bench::trace_overhead::run_env("BENCH_trace_overhead.json") {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("trace_overhead: {e:#}");
            std::process::exit(1);
        }
    }
}
