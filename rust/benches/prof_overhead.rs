//! `cargo bench --bench prof_overhead` — span-profiler cost.
//!
//! Runs the same native CIFAR-scale training job with the profiler
//! disabled and enabled, takes the minimum wall time over its trials,
//! and fails if the profiled arm exceeds 5% overhead (+20 ms slack), if
//! profiling perturbed the trained model, or if kernel + phase spans
//! explain less than 90% of train-step wall time. Report goes to
//! `BENCH_prof_overhead.json` (`FEDSKEL_BENCH_OUT` overrides;
//! `FEDSKEL_BENCH_SMOKE=1` is the small CI profile).

fn main() {
    match fedskel::bench::prof_overhead::run_env("BENCH_prof_overhead.json") {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("prof_overhead: {e:#}");
            std::process::exit(1);
        }
    }
}
