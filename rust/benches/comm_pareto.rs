//! `cargo bench --bench comm_pareto` — comm-vs-accuracy Pareto sweep.
//!
//! Full federated runs on the native lenet backend (pinned batch
//! seconds) per (method × compressor × ratio × error-feedback),
//! reporting measured wire bytes, achieved compression ratio, final
//! accuracy, and time-to-accuracy, written to `BENCH_comm_pareto.json`
//! (`FEDSKEL_BENCH_OUT` overrides; `FEDSKEL_BENCH_SMOKE=1` is the small
//! CI profile; `FEDSKEL_BENCH_ROUNDS` overrides the round count). The
//! bench itself asserts that int8+error-feedback FedSkel cuts ≥ 60% of
//! f32 FedAvg's wire bytes while staying within 0.5 pp of f32 FedSkel's
//! accuracy — a failed assertion fails the bench.

fn main() {
    match fedskel::bench::comm_pareto::run_env("BENCH_comm_pareto.json") {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("comm_pareto: {e:#}");
            std::process::exit(1);
        }
    }
}
