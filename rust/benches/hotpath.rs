//! `cargo bench --bench hotpath` — L3 hot-path microbenchmarks for the
//! performance pass (EXPERIMENTS.md §Perf): per-bucket train-step
//! execution, eval step, host-side aggregation, download masking, and
//! data batching. These isolate the coordinator's own costs from the
//! artifact compute so the perf pass can attribute regressions.

#[cfg(feature = "pjrt")]
use fedskel::aggregate::{self, Update};
#[cfg(feature = "pjrt")]
use fedskel::benchkit::Bench;
#[cfg(feature = "pjrt")]
use fedskel::data::shard::Batcher;
#[cfg(feature = "pjrt")]
use fedskel::data::synthetic::{Dataset, DatasetKind};
#[cfg(feature = "pjrt")]
use fedskel::model::{init_params, Manifest};
#[cfg(feature = "pjrt")]
use fedskel::runtime::step::{Backend, PjrtBackend};
#[cfg(feature = "pjrt")]
use fedskel::skeleton::identity_skeleton;

#[cfg(feature = "pjrt")]
fn main() {
    let dir = std::env::var("FEDSKEL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("hotpath: skipping ({e:#}) — run `make artifacts`");
            return;
        }
    };
    let bench = Bench::new(2, 10);

    // ---- artifact execution per bucket
    let mut backend = PjrtBackend::new(&manifest, "lenet_smnist").expect("backend");
    let spec = backend.spec().clone();
    let params = init_params(&spec, 0);
    let numel: usize = spec.input_shape.iter().product();
    let x = vec![0.1f32; spec.train_batch * numel];
    let y: Vec<i32> = (0..spec.train_batch).map(|i| (i % 10) as i32).collect();
    for bucket in [100usize, 40, 10] {
        let ks = spec.train_artifact(bucket).unwrap().k.clone();
        let skel: Vec<Vec<i32>> = ks.iter().map(|&k| (0..k as i32).collect()).collect();
        // warm the compile cache outside the timer
        backend
            .train_step(bucket, &params, &params, &x, &y, &skel, 0.05, 0.0)
            .expect("warmup");
        bench.run(&format!("train_step lenet r{bucket} (batch {})", spec.train_batch), || {
            backend
                .train_step(bucket, &params, &params, &x, &y, &skel, 0.05, 0.0)
                .expect("train step");
        });
    }

    let xe = vec![0.1f32; spec.eval_batch * numel];
    backend.eval_logits(&params, &xe).expect("warmup");
    bench.run(&format!("eval_step lenet (batch {})", spec.eval_batch), || {
        backend.eval_logits(&params, &xe).expect("eval");
    });

    // ---- host-side aggregation over 32 clients
    let updates: Vec<Update> = (0..32)
        .map(|i| Update {
            client: i,
            weight: 100.0,
            params: init_params(&spec, i as u64),
            skeleton: identity_skeleton(&[6, 16, 120, 84]),
        })
        .collect();
    let global = init_params(&spec, 99);
    bench.run("fedavg aggregate (32 clients, lenet)", || {
        aggregate::fedavg(&global, &updates).expect("fedavg");
    });
    bench.run("fedskel aggregate (32 clients, lenet)", || {
        aggregate::fedskel_aggregate(&global, &updates, &spec.prunable).expect("fedskel");
    });

    // ---- download masking
    let mut local = init_params(&spec, 5);
    let skel: Vec<Vec<i32>> = spec.train_artifact(10).unwrap().k.iter().map(|&k| (0..k as i32).collect()).collect();
    bench.run("apply_download skeleton (lenet r10)", || {
        aggregate::apply_download(&mut local, &global, &spec.prunable, &skel, None).expect("download");
    });

    // ---- batching
    let data = Dataset::generate(DatasetKind::Smnist, 2000, 0);
    let mut batcher = Batcher::new((0..1600).collect(), spec.train_batch, 0);
    let mut bx = vec![0.0f32; spec.train_batch * numel];
    let mut by = vec![0i32; spec.train_batch];
    bench.run("fill_batch smnist (batch 32)", || {
        batcher.fill_batch(&data, &mut bx, &mut by);
    });
}

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!("hotpath: built without the `pjrt` feature — artifact timing needs the PJRT runtime");
}
