//! `cargo bench --bench hotpath` — L3 hot-path microbenchmarks.
//!
//! Default build (no `pjrt`): runs the **native CPU backend** — real
//! forward/backward with skeleton-sliced conv kernels — timing the
//! backward pass and full train step at r100/r50/r25(/r40/r10), swept
//! over the `FEDSKEL_BENCH_THREADS` kernel-thread budgets (default 1,2,4;
//! smoke 1,2), and writes the Table-1 report with its per-thread-count
//! dimension to `BENCH_table1_native.json` (`FEDSKEL_BENCH_OUT`
//! overrides; `FEDSKEL_BENCH_SMOKE=1` runs the 1-sample CI smoke
//! profile). Host-side costs (aggregation, download masking, batching)
//! are timed in both builds.
//!
//! With `pjrt`: additionally times the AOT artifacts per ratio bucket.

use fedskel::aggregate::{self, Update};
use fedskel::benchkit::Bench;
use fedskel::data::shard::Batcher;
use fedskel::data::synthetic::{Dataset, DatasetKind};
use fedskel::model::{init_params, ModelSpec};
use fedskel::skeleton::identity_skeleton;

/// Host-side (backend-independent) hot paths: aggregation over 32
/// clients, skeleton download masking, and minibatch filling.
fn host_side_benches(spec: &ModelSpec, bench: &Bench) {
    let channels: Vec<usize> = spec.prunable.iter().map(|p| p.channels).collect();
    let updates: Vec<Update> = (0..32)
        .map(|i| Update {
            client: i,
            weight: 100.0,
            params: init_params(spec, i as u64),
            skeleton: identity_skeleton(&channels),
        })
        .collect();
    let global = init_params(spec, 99);
    bench.run(&format!("fedavg aggregate (32 clients, {})", spec.name), || {
        aggregate::fedavg(&global, &updates).expect("fedavg");
    });
    bench.run(&format!("fedskel aggregate (32 clients, {})", spec.name), || {
        aggregate::fedskel_aggregate(&global, &updates, &spec.prunable).expect("fedskel");
    });

    let lowest = spec.train_buckets()[0];
    let mut local = init_params(spec, 5);
    let skel: Vec<Vec<i32>> = spec
        .train_artifact(lowest)
        .unwrap()
        .k
        .iter()
        .map(|&k| (0..k as i32).collect())
        .collect();
    bench.run(&format!("apply_download skeleton ({} r{lowest})", spec.name), || {
        aggregate::apply_download(&mut local, &global, &spec.prunable, &skel, None)
            .expect("download");
    });

    let numel: usize = spec.input_shape.iter().product();
    let data = Dataset::generate(DatasetKind::Smnist, 2000, 0);
    let mut batcher = Batcher::new((0..1600).collect(), spec.train_batch, 0);
    let mut bx = vec![0.0f32; spec.train_batch * numel];
    let mut by = vec![0i32; spec.train_batch];
    bench.run(&format!("fill_batch smnist (batch {})", spec.train_batch), || {
        batcher.fill_batch(&data, &mut bx, &mut by);
    });
}

#[cfg(not(feature = "pjrt"))]
fn main() {
    // ---- the Table-1 native measurement (writes BENCH_table1_native.json)
    match fedskel::bench::table1_native::run_env("BENCH_table1_native.json") {
        Ok(report) => println!("\n{report}\n"),
        Err(e) => {
            eprintln!("hotpath: native table1 failed: {e:#}");
            std::process::exit(1);
        }
    }

    // ---- host-side hot paths at LeNet scale
    let model = fedskel::runtime::NativeModel::lenet();
    host_side_benches(&model.spec, &Bench::new(1, 5));
}

#[cfg(feature = "pjrt")]
fn main() {
    use fedskel::model::Manifest;
    use fedskel::runtime::step::{Backend, PjrtBackend};

    let dir = std::env::var("FEDSKEL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("hotpath: skipping ({e:#}) — run `make artifacts`");
            return;
        }
    };
    let bench = Bench::new(2, 10);

    // ---- artifact execution per bucket
    let mut backend = PjrtBackend::new(&manifest, "lenet_smnist").expect("backend");
    let spec = backend.spec().clone();
    let params = init_params(&spec, 0);
    let numel: usize = spec.input_shape.iter().product();
    let x = vec![0.1f32; spec.train_batch * numel];
    let y: Vec<i32> = (0..spec.train_batch).map(|i| (i % 10) as i32).collect();
    for bucket in [100usize, 40, 10] {
        let ks = spec.train_artifact(bucket).unwrap().k.clone();
        let skel: Vec<Vec<i32>> = ks.iter().map(|&k| (0..k as i32).collect()).collect();
        // warm the compile cache outside the timer
        backend
            .train_step(bucket, &params, &params, &x, &y, &skel, 0.05, 0.0)
            .expect("warmup");
        bench.run(&format!("train_step lenet r{bucket} (batch {})", spec.train_batch), || {
            backend
                .train_step(bucket, &params, &params, &x, &y, &skel, 0.05, 0.0)
                .expect("train step");
        });
    }

    let xe = vec![0.1f32; spec.eval_batch * numel];
    backend.eval_logits(&params, &xe).expect("warmup");
    bench.run(&format!("eval_step lenet (batch {})", spec.eval_batch), || {
        backend.eval_logits(&params, &xe).expect("eval");
    });

    host_side_benches(&spec, &bench);
}
