//! `cargo bench --bench table1_speedup` — regenerates paper Table 1:
//! conv back-prop and overall train-step speedups per skeleton ratio.
//!
//! Default build: the **native CPU backend** (real skeleton-sliced
//! kernels, no artifacts needed); the report also lands in
//! `BENCH_table1_native.json`. With `pjrt`: the AOT artifacts.
//! (benchkit harness; criterion is unavailable offline — DESIGN.md §3.)

#[cfg(feature = "pjrt")]
use fedskel::model::Manifest;

#[cfg(feature = "pjrt")]
fn main() {
    let dir = std::env::var("FEDSKEL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("table1_speedup: skipping ({e:#}) — run `make artifacts`");
            return;
        }
    };
    let samples = std::env::var("FEDSKEL_BENCH_SAMPLES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);
    match fedskel::bench::table1::run(&manifest, &[40, 30, 20, 10], samples) {
        Ok(report) => println!("\n{report}"),
        Err(e) => {
            eprintln!("table1_speedup failed: {e:#}");
            std::process::exit(1);
        }
    }
}

#[cfg(not(feature = "pjrt"))]
fn main() {
    match fedskel::bench::table1_native::run_env("BENCH_table1_native.json") {
        Ok(report) => println!("\n{report}"),
        Err(e) => {
            eprintln!("table1_speedup (native) failed: {e:#}");
            std::process::exit(1);
        }
    }
}
