//! `cargo bench --bench table2_comm` — regenerates paper Table 2:
//! total parameter-communication volume per method at the paper's scale
//! (100 clients × 1000 rounds, LeNet, FedSkel r = 10%).

use fedskel::model::Manifest;

fn main() {
    let dir = std::env::var("FEDSKEL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("table2_comm: skipping ({e:#}) — run `make artifacts`");
            return;
        }
    };
    match fedskel::bench::table2::run(&manifest, "lenet_smnist", 100, 1000, 10) {
        Ok(report) => println!("\n{report}"),
        Err(e) => {
            eprintln!("table2_comm failed: {e:#}");
            std::process::exit(1);
        }
    }
}
