//! `cargo bench --bench checkpoint_overhead` — snapshot-write cost.
//!
//! Runs the same native-backend training job with and without
//! `--checkpoint-every 1` snapshots, takes the minimum wall time over
//! its trials, and fails if the checkpointing arm exceeds 5% overhead
//! (+20 ms slack), if checkpointing perturbed the trained model, or if
//! the final snapshot does not restore to the same digest. Report goes
//! to `BENCH_checkpoint.json` (`FEDSKEL_BENCH_OUT` overrides;
//! `FEDSKEL_BENCH_SMOKE=1` is the small CI profile).

fn main() {
    match fedskel::bench::checkpoint_overhead::run_env("BENCH_checkpoint.json") {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("checkpoint_overhead: {e:#}");
            std::process::exit(1);
        }
    }
}
