//! Tables 3 & 4: accuracy of FedAvg / FedMTL / LG-FedAvg / FedSkel under
//! the paper's New-Test / Local-Test protocol.
//!
//! Table 3: four datasets with LeNet. Table 4: LeNet + ResNet-18/34 on
//! synthetic-CIFAR-10. Scale knobs default to a single-core-CPU budget
//! (the paper used 100 clients × 1000 epochs on real hardware); pass
//! `--clients/--rounds/--dataset-size` to scale up. Results append to
//! `results/baseline_comparison.csv`.
//!
//! Run: `cargo run --release --example baseline_comparison -- --table 3`
//!      `cargo run --release --example baseline_comparison -- --table 4`

#[cfg(feature = "pjrt")]
use anyhow::Result;

#[cfg(feature = "pjrt")]
use fedskel::config::{Method, RunConfig};
#[cfg(feature = "pjrt")]
use fedskel::coordinator::Coordinator;
#[cfg(feature = "pjrt")]
use fedskel::data::DatasetKind;
#[cfg(feature = "pjrt")]
use fedskel::metrics::Table;
#[cfg(feature = "pjrt")]
use fedskel::model::Manifest;
#[cfg(feature = "pjrt")]
use fedskel::runtime::PjrtBackend;
#[cfg(feature = "pjrt")]
use fedskel::util::cli::Cli;
#[cfg(feature = "pjrt")]
use fedskel::util::timer::Timer;

#[cfg(feature = "pjrt")]
struct Cell {
    new_acc: f64,
    local_acc: f64,
}

#[cfg(feature = "pjrt")]
fn run_one(
    manifest: &Manifest,
    method: Method,
    dataset: DatasetKind,
    model: &str,
    args: &Scale,
) -> Result<Cell> {
    let cfg = RunConfig {
        method,
        dataset,
        model: model.into(),
        num_clients: args.clients,
        shards_per_client: if dataset.num_classes() >= 62 { 20 } else { 2 },
        dataset_size: args.dataset_size.max(dataset.num_classes() * 24),
        new_test_size: 256,
        rounds: args.rounds,
        local_steps: args.local_steps,
        updateskel_per_setskel: 3,
        lr: args.lr,
        mu: if method == Method::FedMtl { 0.5 } else { 0.0 },
        eval_every: 0,
        seed: args.seed,
        artifacts_dir: args.artifacts.clone(),
        ..RunConfig::default()
    };
    let backend = PjrtBackend::new(manifest, model)?;
    let mut coord = Coordinator::new(cfg, backend)?;
    let t = Timer::start();
    coord.run()?;
    let new_acc = coord.log.last_new_acc().unwrap_or(0.0);
    let local_acc = coord.log.last_local_acc().unwrap_or(0.0);
    eprintln!(
        "  {:<9} {:<18} new {:>6.2}%  local {:>6.2}%   ({:.0}s)",
        method.name(),
        model,
        new_acc * 100.0,
        local_acc * 100.0,
        t.elapsed_secs()
    );
    Ok(Cell { new_acc, local_acc })
}

#[cfg(feature = "pjrt")]
struct Scale {
    clients: usize,
    rounds: usize,
    local_steps: usize,
    dataset_size: usize,
    lr: f32,
    seed: u64,
    artifacts: String,
}

#[cfg(feature = "pjrt")]
fn main() -> Result<()> {
    let cli = Cli::new("baseline_comparison", "Tables 3/4 accuracy comparison")
        .flag("table", Some("3"), "which table: 3 (datasets x LeNet) or 4 (models x scifar10)")
        .flag("clients", Some("8"), "clients")
        .flag("rounds", Some("16"), "rounds")
        .flag("local-steps", Some("4"), "local batches per round")
        .flag("dataset-size", Some("2000"), "synthesized samples")
        .flag("lr", Some("0.06"), "learning rate")
        .flag("seed", Some("3"), "seed")
        .flag("artifacts", Some("artifacts"), "artifacts dir")
        .flag("out", Some("results/baseline_comparison.csv"), "CSV output");
    let args = cli.parse()?;
    let scale = Scale {
        clients: args.usize("clients")?,
        rounds: args.usize("rounds")?,
        local_steps: args.usize("local-steps")?,
        dataset_size: args.usize("dataset-size")?,
        lr: args.f32("lr")?,
        seed: args.u64("seed")?,
        artifacts: args.str("artifacts")?.to_string(),
    };
    let manifest = Manifest::load(&scale.artifacts)?;
    let table_id = args.usize("table")?;

    // (column label, dataset, model)
    let columns: Vec<(String, DatasetKind, String)> = if table_id == 3 {
        [
            DatasetKind::Smnist,
            DatasetKind::Sfemnist,
            DatasetKind::Scifar10,
            DatasetKind::Scifar100,
        ]
        .into_iter()
        .map(|d| (d.name().to_string(), d, d.lenet_model().to_string()))
        .collect()
    } else {
        vec![
            ("LeNet".into(), DatasetKind::Scifar10, "lenet_scifar10".into()),
            ("ResNet-18".into(), DatasetKind::Scifar10, "resnet18_scifar10".into()),
            ("ResNet-34".into(), DatasetKind::Scifar10, "resnet34_scifar10".into()),
        ]
    };
    let methods = [Method::FedAvg, Method::FedMtl, Method::LgFedAvg, Method::FedSkel];

    let mut header = vec!["Method".to_string(), "Test".to_string()];
    header.extend(columns.iter().map(|(l, _, _)| l.clone()));
    let mut t = Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let mut csv = String::from("table,method,column,new_acc,local_acc\n");

    for method in methods {
        let mut new_row = vec![method.name().to_string(), "New".to_string()];
        let mut local_row = vec![String::new(), "Local".to_string()];
        for (label, dataset, model) in &columns {
            let cell = run_one(&manifest, method, *dataset, model, &scale)?;
            new_row.push(format!("{:.2}", cell.new_acc * 100.0));
            local_row.push(format!("{:.2}", cell.local_acc * 100.0));
            csv.push_str(&format!(
                "{},{},{},{:.4},{:.4}\n",
                table_id,
                method.name(),
                label,
                cell.new_acc,
                cell.local_acc
            ));
        }
        t.row(new_row);
        t.row(local_row);
    }

    println!(
        "\nTable {} — accuracy (%) under New/Local test, {} clients x {} rounds\n{}",
        table_id,
        scale.clients,
        scale.rounds,
        t.render()
    );
    let out = args.str("out")?;
    if let Some(dir) = std::path::Path::new(out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(out, csv)?;
    println!("wrote {out}");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!(
        "baseline_comparison: this example drives the real AOT artifacts and needs the \
         `pjrt` feature (cargo run --features pjrt --example baseline_comparison). \
         The transport_demo example runs without it."
    );
}
