//! Table 2: parameter-communication volumes for the four methods.
//!
//! Pure accounting over the comm substrate — replays each method's
//! exchange schedule (FedSkel: 1 full SetSkel round per 3 skeleton-only
//! UpdateSkel rounds) at the paper's scale (100 clients × 1000 rounds).
//!
//! Run: `cargo run --release --example comm_report`

use fedskel::bench::table2;
use fedskel::model::Manifest;
use fedskel::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("comm_report", "Table 2 communication accounting")
        .flag("artifacts", Some("artifacts"), "artifacts dir")
        .flag("model", Some("lenet_smnist"), "manifest model")
        .flag("clients", Some("100"), "clients")
        .flag("rounds", Some("1000"), "rounds")
        .flag("ratio", Some("10"), "FedSkel skeleton ratio %");
    let args = cli.parse()?;

    let manifest = Manifest::load(args.str("artifacts")?)?;
    let report = table2::run(
        &manifest,
        args.str("model")?,
        args.usize("clients")?,
        args.usize("rounds")?,
        args.usize("ratio")?,
    )?;
    println!("{report}");
    println!(
        "paper Table 2 reference (LeNet/MNIST): FedAvg 12.8e9, FedMTL -6.3%,\n\
         LG-FedAvg -33.6%, FedSkel(r=10%) -64.8%. See EXPERIMENTS.md for the\n\
         accounting-protocol differences on the baselines."
    );
    Ok(())
}
