//! Table 2: communication volumes for the four methods — parameter
//! counts (the paper's unit) and *measured wire bytes* (exact transport
//! frame sizes from the wire codec).
//!
//! Pure accounting over the comm substrate — replays each method's
//! exchange schedule (FedSkel: 1 full SetSkel round per 3 skeleton-only
//! UpdateSkel rounds) at the paper's scale (100 clients × 1000 rounds).
//!
//! Run: `cargo run --release --example comm_report`

use fedskel::bench::table2;
use fedskel::model::Manifest;
use fedskel::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("comm_report", "Table 2 communication accounting")
        .flag("artifacts", Some("artifacts"), "artifacts dir")
        .flag("model", Some("lenet_smnist"), "manifest model")
        .flag("clients", Some("100"), "clients")
        .flag("rounds", Some("1000"), "rounds")
        .flag("ratio", Some("10"), "FedSkel skeleton ratio %");
    let args = cli.parse()?;

    let manifest = Manifest::load(args.str("artifacts")?)?;
    let model = args.str("model")?;
    let clients = args.usize("clients")?;
    let rounds = args.usize("rounds")?;
    let ratio = args.usize("ratio")?;

    let rows = table2::run_rows(&manifest, model, clients, rounds, ratio)?;
    println!("{}", table2::render(&rows, model, clients, rounds, ratio));

    let fedavg = rows.iter().find(|r| r.method == "fedavg").expect("fedavg row");
    let fedskel = rows.iter().find(|r| r.method == "fedskel").expect("fedskel row");
    println!(
        "FedSkel (r = {ratio}%) vs FedAvg on the wire: {:.3e} -> {:.3e} bytes \
         ({:.1}% fewer bytes; {:.1}% fewer parameters)",
        fedavg.wire_bytes as f64,
        fedskel.wire_bytes as f64,
        fedskel.wire_reduction_pct,
        fedskel.reduction_pct,
    );
    println!(
        "paper Table 2 reference (LeNet/MNIST): FedAvg 12.8e9, FedMTL -6.3%,\n\
         LG-FedAvg -33.6%, FedSkel(r=10%) -64.8%. The wire-byte reduction sits\n\
         slightly below the parameter reduction because skeleton frames also\n\
         carry channel indices. See EXPERIMENTS.md for the accounting-protocol\n\
         differences on the baselines."
    );
    Ok(())
}
