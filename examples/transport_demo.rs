//! Transport-layer demo: a full federated run whose every payload moves
//! through the wire codec and a pluggable transport, with clients
//! training concurrently on the worker pool.
//!
//! Runs entirely on the deterministic mock backend — no AOT artifacts or
//! PJRT needed — so it works on a fresh checkout:
//!
//!   cargo run --release --example transport_demo
//!   cargo run --release --example transport_demo -- --workers 8 --quant f16
//!
//! Prints per-round measured wire bytes and the FedSkel-vs-FedAvg byte
//! reduction the codec actually achieves.

use anyhow::Result;

use fedskel::config::{Method, RunConfig};
use fedskel::coordinator::Coordinator;
use fedskel::metrics::Table;
use fedskel::runtime::mock::MockBackend;
use fedskel::transport::wire::Quant;
use fedskel::transport::TransportKind;
use fedskel::util::cli::Cli;

fn run_method(method: Method, workers: usize, quant: Quant, rounds: usize) -> Result<Coordinator<MockBackend>> {
    let cfg = RunConfig {
        method,
        model: "toy".into(),
        num_clients: 8,
        shards_per_client: 2,
        dataset_size: 800,
        new_test_size: 128,
        rounds,
        local_steps: 3,
        updateskel_per_setskel: 3,
        eval_every: 0,
        transport: TransportKind::Loopback,
        quant,
        seed: 17,
        ..RunConfig::default()
    };
    let mut coord = if workers > 0 {
        let backends: Vec<MockBackend> = (0..workers).map(|_| MockBackend::toy()).collect();
        Coordinator::with_pool(cfg, MockBackend::toy(), backends)?
    } else {
        Coordinator::new(cfg, MockBackend::toy())?
    };
    coord.run()?;
    Ok(coord)
}

fn main() -> Result<()> {
    let cli = Cli::new("transport_demo", "wire codec + worker pool end-to-end (mock backend)")
        .flag("workers", Some("4"), "client worker threads (0 = inline)")
        .flag("quant", Some("f32"), "wire quantization: f32|f16|int8")
        .flag("rounds", Some("8"), "federated rounds");
    let args = cli.parse()?;
    let workers = args.usize("workers")?;
    let quant = Quant::parse(args.str("quant")?)?;
    let rounds = args.usize("rounds")?;

    println!(
        "transport_demo: loopback transport, {} quantization, {} worker(s)\n",
        quant.name(),
        workers
    );

    let skel = run_method(Method::FedSkel, workers, quant, rounds)?;
    println!("FedSkel per-round wire traffic:");
    for r in &skel.log.rounds {
        println!(
            "  round {:>2} [{:<10}] {:>8} params  {:>8} wire bytes",
            r.round, r.phase, r.comm_params, r.comm_wire_bytes
        );
    }

    let avg = run_method(Method::FedAvg, workers, quant, rounds)?;
    let mut t = Table::new(&["Method", "Params", "Wire bytes", "Byte reduction"]);
    t.row(vec![
        "FedAvg".into(),
        format!("{}", avg.ledger.total_params()),
        format!("{}", avg.ledger.total_wire_bytes()),
        "-".into(),
    ]);
    t.row(vec![
        "FedSkel".into(),
        format!("{}", skel.ledger.total_params()),
        format!("{}", skel.ledger.total_wire_bytes()),
        format!("{:.1}%", skel.ledger.wire_reduction_vs(&avg.ledger)),
    ]);
    println!("\n{}", t.render());
    println!(
        "final FedSkel accuracy — new: {:.1}%  local: {:.1}%  (trained on {} workers)",
        skel.log.last_new_acc().unwrap_or(0.0) * 100.0,
        skel.log.last_local_acc().unwrap_or(0.0) * 100.0,
        skel.workers().max(1),
    );
    Ok(())
}
