//! Figure 5 scenario: an 8-device heterogeneous fleet.
//!
//! Measures the real per-bucket artifact batch times on the host, then
//! simulates the paper's 8 Raspberry-Pi fleet with equidistant compute
//! capabilities: FedAvg makes every device run the full model (stragglers
//! dominate); FedSkel assigns `r_i ∝ c_i` so the per-device bars flatten.
//!
//! Run: `cargo run --release --example heterogeneous_system [-- --devices 8]`

#[cfg(feature = "pjrt")]
use fedskel::bench::fig5;
#[cfg(feature = "pjrt")]
use fedskel::model::Manifest;
#[cfg(feature = "pjrt")]
use fedskel::util::cli::Cli;

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!(
        "heterogeneous_system: this example times real AOT artifacts and needs \
         the `pjrt` feature (cargo run --features pjrt --example heterogeneous_system)."
    );
}

#[cfg(feature = "pjrt")]
fn main() -> anyhow::Result<()> {
    let cli = Cli::new("heterogeneous_system", "Fig. 5 heterogeneous-fleet simulation")
        .flag("artifacts", Some("artifacts"), "artifacts dir")
        .flag("devices", Some("8"), "fleet size")
        .flag("samples", Some("5"), "timing samples per bucket");
    let args = cli.parse()?;

    let manifest = Manifest::load(args.str("artifacts")?)?;
    let res = fig5::run_result(&manifest, args.usize("devices")?, args.usize("samples")?)?;
    println!("{}", fig5::render(&res));

    // paper claim: up to 1.82x whole-system speedup from workload balance
    println!(
        "paper Fig.5 reference: FedSkel balances an 8-Pi fleet to ~1.82x;\n\
         this testbed: {:.2}x (imbalance {:.2} -> {:.2})",
        res.system_speedup(),
        res.fedavg_imbalance,
        res.fedskel_imbalance
    );
    Ok(())
}
