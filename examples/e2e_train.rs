//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Proves the layers compose on a real workload: a full FedSkel system —
//! synthetic-MNIST non-IID across 10 heterogeneous clients, LeNet-5 —
//! trained end-to-end, logging the loss curve and accuracy trajectory to
//! `results/e2e_loss.csv`. With the `pjrt` feature the model runs as
//! Pallas-kernel AOT artifacts on the PJRT runtime; the default build
//! trains on the native CPU backend (`runtime::native`, real
//! skeleton-sliced kernels) so the example works everywhere.
//!
//! Run: `cargo run --release --example e2e_train [-- --rounds N]`

#[cfg(feature = "pjrt")]
use fedskel::config::{standard_flags, Method, RunConfig};
#[cfg(feature = "pjrt")]
use fedskel::coordinator::Coordinator;
#[cfg(feature = "pjrt")]
use fedskel::model::Manifest;
#[cfg(feature = "pjrt")]
use fedskel::runtime::step::Backend;
#[cfg(feature = "pjrt")]
use fedskel::runtime::PjrtBackend;
#[cfg(feature = "pjrt")]
use fedskel::util::cli::Cli;
#[cfg(feature = "pjrt")]
use fedskel::util::timer::Timer;

#[cfg(feature = "pjrt")]
fn main() -> anyhow::Result<()> {
    let cli = standard_flags(Cli::new("e2e_train", "end-to-end FedSkel training driver"))
        .flag("out", Some("results/e2e_loss.csv"), "loss-curve CSV path");
    let args = cli.parse()?;
    let mut cfg = RunConfig {
        method: Method::FedSkel,
        model: "lenet_smnist".into(),
        num_clients: 10,
        dataset_size: 3000,
        new_test_size: 512,
        rounds: 24,
        local_steps: 4,
        updateskel_per_setskel: 3,
        eval_every: 4,
        lr: 0.06,
        seed: 7,
        ..RunConfig::default()
    };
    cfg.apply_args(&args)?;

    let total = Timer::start();
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let backend = PjrtBackend::new(&manifest, &cfg.model)?;
    let mut coord = Coordinator::new(cfg.clone(), backend)?;

    println!(
        "E2E: {} clients x {} rounds x {} local steps (batch {}) on {} — {} params",
        cfg.num_clients,
        cfg.rounds,
        cfg.local_steps,
        coord.backend.spec().train_batch,
        cfg.dataset.name(),
        coord.backend.spec().num_params,
    );
    for r in 0..cfg.rounds {
        coord.step_round()?;
        let log = coord.log.rounds.last().unwrap();
        println!(
            "round {r:>3} [{:<10}] loss {:.4}  sim {:.2}s  wall {:.1}s{}",
            log.phase,
            log.mean_loss,
            log.sim_round_secs,
            log.wall_secs,
            log.new_acc
                .map(|a| format!("  new {:.1}%  local {:.1}%", a * 100.0, log.local_acc.unwrap() * 100.0))
                .unwrap_or_default()
        );
    }
    let new_acc = coord.evaluate_new()?;
    let local_acc = coord.evaluate_local()?;

    let out = args.str("out")?;
    if let Some(dir) = std::path::Path::new(out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    coord.log.save_csv(out)?;

    println!("\n=== E2E summary ===");
    println!("steps executed: {}", cfg.rounds * cfg.local_steps * cfg.num_clients);
    println!(
        "loss: {:.4} -> {:.4}",
        coord.log.rounds.first().unwrap().mean_loss,
        coord.log.rounds.last().unwrap().mean_loss
    );
    println!("New test  {:.2}%", new_acc * 100.0);
    println!("Local test {:.2}%", local_acc * 100.0);
    println!("comm total {} params", coord.ledger.total_params());
    println!("wall time {:.1}s", total.elapsed_secs());
    println!("loss curve written to {out}");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn main() -> anyhow::Result<()> {
    use fedskel::config::{standard_flags, Method, RunConfig};
    use fedskel::coordinator::Coordinator;
    use fedskel::runtime::step::Backend;
    use fedskel::runtime::NativeBackend;
    use fedskel::util::cli::Cli;
    use fedskel::util::timer::Timer;

    let cli = standard_flags(Cli::new("e2e_train", "end-to-end FedSkel training driver (native)"))
        .flag("out", Some("results/e2e_loss.csv"), "loss-curve CSV path");
    let args = cli.parse()?;
    let mut cfg = RunConfig {
        method: Method::FedSkel,
        model: "lenet_native".into(),
        num_clients: 10,
        dataset_size: 3000,
        new_test_size: 512,
        rounds: 12,
        local_steps: 4,
        updateskel_per_setskel: 3,
        eval_every: 4,
        lr: 0.06,
        seed: 7,
        ..RunConfig::default()
    };
    cfg.apply_args(&args)?;
    // same guard as `fedskel train` (native): this driver ships exactly
    // one model — refuse other datasets/models instead of panicking on a
    // batch-geometry mismatch mid-round
    if cfg.dataset != fedskel::data::DatasetKind::Smnist {
        anyhow::bail!(
            "the native e2e driver ships LeNet for smnist only — build with --features pjrt for {}",
            cfg.dataset.name()
        );
    }
    match cfg.model.as_str() {
        "lenet_native" | "lenet_smnist" => cfg.model = "lenet_native".into(),
        other => anyhow::bail!(
            "the native e2e driver only ships lenet_native (got --model {other})"
        ),
    }

    let total = Timer::start();
    let backend = NativeBackend::lenet()
        .with_parallelism(fedskel::kernels::Parallelism::new(cfg.threads));
    let mut coord = Coordinator::new(cfg.clone(), backend)?;

    println!(
        "E2E (native CPU): {} clients x {} rounds x {} local steps (batch {}) on {} — {} params",
        cfg.num_clients,
        cfg.rounds,
        cfg.local_steps,
        coord.backend.spec().train_batch,
        cfg.dataset.name(),
        coord.backend.spec().num_params,
    );
    for r in 0..cfg.rounds {
        coord.step_round()?;
        let log = coord.log.rounds.last().unwrap();
        println!(
            "round {r:>3} [{:<10}] loss {:.4}  sim {:.2}s  wall {:.1}s{}",
            log.phase,
            log.mean_loss,
            log.sim_round_secs,
            log.wall_secs,
            log.new_acc
                .map(|a| format!("  new {:.1}%  local {:.1}%", a * 100.0, log.local_acc.unwrap() * 100.0))
                .unwrap_or_default()
        );
    }
    let new_acc = coord.evaluate_new()?;
    let local_acc = coord.evaluate_local()?;

    let out = args.str("out")?;
    if let Some(dir) = std::path::Path::new(out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    coord.log.save_csv(out)?;

    println!("\n=== E2E summary (native backend) ===");
    println!("steps executed: {}", cfg.rounds * cfg.local_steps * cfg.num_clients);
    println!(
        "loss: {:.4} -> {:.4}",
        coord.log.rounds.first().unwrap().mean_loss,
        coord.log.rounds.last().unwrap().mean_loss
    );
    println!("New test  {:.2}%", new_acc * 100.0);
    println!("Local test {:.2}%", local_acc * 100.0);
    println!("comm total {} params", coord.ledger.total_params());
    println!("wall time {:.1}s", total.elapsed_secs());
    println!("loss curve written to {out}");
    Ok(())
}
