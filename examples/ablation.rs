//! Ablation bench over FedSkel's design choices (DESIGN.md calls these
//! out; the paper's §5 lists them as future work):
//!
//!   1. skeleton-selection metric — Eq. 2 activation importance vs
//!      weight-norm vs random vs adversarial least-important;
//!   2. SetSkel : UpdateSkel cadence — 1:1 / 1:3 / 1:5;
//!   3. robustness — client dropout at 0% / 30%.
//!
//! Each cell is a full FedSkel run at fixed scale; outputs accuracy and
//! total communication. Appends CSV to `results/ablation.csv`.
//!
//! Run: `cargo run --release --example ablation`

#[cfg(feature = "pjrt")]
use anyhow::Result;

#[cfg(feature = "pjrt")]
use fedskel::config::{Method, RunConfig};
#[cfg(feature = "pjrt")]
use fedskel::coordinator::Coordinator;
#[cfg(feature = "pjrt")]
use fedskel::metrics::Table;
#[cfg(feature = "pjrt")]
use fedskel::model::Manifest;
#[cfg(feature = "pjrt")]
use fedskel::runtime::PjrtBackend;
#[cfg(feature = "pjrt")]
use fedskel::skeleton::SelectionMetric;
#[cfg(feature = "pjrt")]
use fedskel::util::cli::Cli;

#[cfg(feature = "pjrt")]
struct Outcome {
    new_acc: f64,
    local_acc: f64,
    comm: u64,
}

#[cfg(feature = "pjrt")]
fn run_cell(manifest: &Manifest, mutate: impl FnOnce(&mut RunConfig), base: &RunConfig) -> Result<Outcome> {
    let mut cfg = base.clone();
    mutate(&mut cfg);
    let backend = PjrtBackend::new(manifest, &cfg.model)?;
    let mut coord = Coordinator::new(cfg, backend)?;
    coord.run()?;
    Ok(Outcome {
        new_acc: coord.log.last_new_acc().unwrap_or(0.0) * 100.0,
        local_acc: coord.log.last_local_acc().unwrap_or(0.0) * 100.0,
        comm: coord.ledger.total_params(),
    })
}

#[cfg(feature = "pjrt")]
fn main() -> Result<()> {
    let cli = Cli::new("ablation", "FedSkel design-choice ablations")
        .flag("artifacts", Some("artifacts"), "artifacts dir")
        .flag("clients", Some("6"), "clients")
        .flag("rounds", Some("12"), "rounds")
        .flag("out", Some("results/ablation.csv"), "CSV output");
    let args = cli.parse()?;
    let manifest = Manifest::load(args.str("artifacts")?)?;
    let base = RunConfig {
        method: Method::FedSkel,
        model: "lenet_smnist".into(),
        num_clients: args.usize("clients")?,
        dataset_size: 1500,
        new_test_size: 256,
        rounds: args.usize("rounds")?,
        local_steps: 3,
        updateskel_per_setskel: 3,
        eval_every: 0,
        lr: 0.06,
        seed: 11,
        artifacts_dir: args.str("artifacts")?.to_string(),
        ..RunConfig::default()
    };

    let mut t = Table::new(&["ablation", "variant", "New %", "Local %", "comm params"]);
    let mut csv = String::from("ablation,variant,new_acc,local_acc,comm_params\n");
    let mut record = |t: &mut Table, csv: &mut String, group: &str, variant: &str, o: Outcome| {
        t.row(vec![
            group.into(),
            variant.into(),
            format!("{:.2}", o.new_acc),
            format!("{:.2}", o.local_acc),
            format!("{}", o.comm),
        ]);
        csv.push_str(&format!("{group},{variant},{:.4},{:.4},{}\n", o.new_acc, o.local_acc, o.comm));
    };

    // 1. selection metric
    for metric in [
        SelectionMetric::Activation,
        SelectionMetric::WeightNorm,
        SelectionMetric::Random,
        SelectionMetric::LeastImportant,
    ] {
        eprintln!("metric = {}...", metric.name());
        let o = run_cell(&manifest, |c| c.selection_metric = metric, &base)?;
        record(&mut t, &mut csv, "metric", metric.name(), o);
    }

    // 2. SetSkel cadence
    for cadence in [1usize, 3, 5] {
        eprintln!("cadence = 1:{cadence}...");
        let o = run_cell(&manifest, |c| c.updateskel_per_setskel = cadence, &base)?;
        record(&mut t, &mut csv, "cadence", &format!("1:{cadence}"), o);
    }

    // 3. dropout robustness
    for dropout in [0.0f64, 0.3] {
        eprintln!("dropout = {dropout}...");
        let o = run_cell(&manifest, |c| c.dropout = dropout, &base)?;
        record(&mut t, &mut csv, "dropout", &format!("{:.0}%", dropout * 100.0), o);
    }

    println!(
        "\nFedSkel ablations ({} clients x {} rounds, lenet_smnist)\n{}",
        base.num_clients,
        base.rounds,
        t.render()
    );
    let out = args.str("out")?;
    if let Some(dir) = std::path::Path::new(out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(out, csv)?;
    println!("wrote {out}");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!(
        "ablation: this example drives the real AOT artifacts and needs the \
         `pjrt` feature (cargo run --features pjrt --example ablation). \
         The transport_demo example runs without it."
    );
}
