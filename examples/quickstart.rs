//! Quickstart: the smallest end-to-end FedSkel run.
//!
//! 8 clients, synthetic-MNIST, LeNet-5, 8 rounds (2 SetSkel + 6 UpdateSkel),
//! heterogeneous ratios 10%–100%. Prints per-round loss/comm and the final
//! New/Local test accuracies.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`)

#[cfg(feature = "pjrt")]
use fedskel::config::{Method, RunConfig};
#[cfg(feature = "pjrt")]
use fedskel::coordinator::Coordinator;
#[cfg(feature = "pjrt")]
use fedskel::model::Manifest;
#[cfg(feature = "pjrt")]
use fedskel::runtime::PjrtBackend;

#[cfg(feature = "pjrt")]
fn main() -> anyhow::Result<()> {
    let cfg = RunConfig {
        method: Method::FedSkel,
        model: "lenet_smnist".into(),
        num_clients: 8,
        rounds: 8,
        local_steps: 4,
        updateskel_per_setskel: 3,
        eval_every: 4,
        lr: 0.06,
        seed: 1,
        ..RunConfig::default()
    };

    println!("FedSkel quickstart — {}", cfg.to_json().to_string());
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let backend = PjrtBackend::new(&manifest, &cfg.model)?;
    let mut coord = Coordinator::new(cfg.clone(), backend)?;

    println!(
        "client ratios: {:?}",
        coord.clients.iter().map(|c| format!("r{}%", c.bucket)).collect::<Vec<_>>()
    );
    for r in 0..cfg.rounds {
        coord.step_round()?;
        let log = coord.log.rounds.last().unwrap();
        println!(
            "round {r:>2} [{:<10}] loss {:.3}  comm {:>8} params{}",
            log.phase,
            log.mean_loss,
            log.comm_params,
            log.new_acc
                .map(|a| format!("  new {:.1}% local {:.1}%", a * 100.0, log.local_acc.unwrap() * 100.0))
                .unwrap_or_default()
        );
    }
    let new_acc = coord.evaluate_new()?;
    let local_acc = coord.evaluate_local()?;
    println!("\nfinal:  New test {:.2}%   Local test {:.2}%", new_acc * 100.0, local_acc * 100.0);
    println!(
        "total communication: {} params ({:.1} MB at f32)",
        coord.ledger.total_params(),
        coord.ledger.total_bytes() as f64 / 1e6
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!(
        "quickstart: this example drives the real AOT artifacts and needs the \
         `pjrt` feature (cargo run --features pjrt --example quickstart). \
         The transport_demo example runs without it."
    );
}
