"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact (up to float accumulation
order) counterpart here. pytest checks ``kernels.* == ref.*`` over
randomized shape sweeps — this file is the correctness ground truth for the
whole L1 layer, so keep it boring: plain jnp, no tiling, no tricks.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain dense matmul ``a @ b`` with f32 accumulation."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def matmul_bias(a: jnp.ndarray, b: jnp.ndarray, bias: jnp.ndarray) -> jnp.ndarray:
    """Fused ``a @ b + bias`` (bias broadcast over rows)."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32) + bias[None, :]


def skeleton_bwd(
    dz: jnp.ndarray,
    a: jnp.ndarray,
    w: jnp.ndarray,
    idx: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Reference structured-pruned backward pass for ``z = a @ w + b``.

    The paper's skeleton-gradient update (Fig. 3): the output-channel
    gradient ``dz`` is pruned to the skeleton channels ``idx`` (a dense
    gather, NOT a mask — the compute genuinely shrinks), then:

      * ``dw_s = a.T @ dz[:, idx]``      — skeleton columns of dW
      * ``db_s = sum(dz[:, idx], 0)``    — skeleton entries of db
      * ``da   = dz[:, idx] @ w[:, idx].T`` — input gradient through the
        skeleton channels only

    Returns ``(da, dw_s, db_s)`` with shapes ``[M,K]``, ``[K,k]``, ``[k]``
    where ``k = len(idx)``.
    """
    dz_s = jnp.take(dz, idx, axis=1)
    dw_s = jnp.matmul(a.T, dz_s, preferred_element_type=jnp.float32)
    db_s = jnp.sum(dz_s, axis=0)
    w_s = jnp.take(w, idx, axis=1)
    da = jnp.matmul(dz_s, w_s.T, preferred_element_type=jnp.float32)
    return da, dw_s, db_s


def masked_bwd(
    dz: jnp.ndarray,
    a: jnp.ndarray,
    w: jnp.ndarray,
    mask: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Mask-based (non-gathered) variant: same semantics as ``skeleton_bwd``
    but keeping full shapes — the oracle for testing that gather+scatter
    round-trips equal masking. ``mask`` is f32 0/1 of shape ``[N]``.
    """
    dz_m = dz * mask[None, :]
    dw = jnp.matmul(a.T, dz_m, preferred_element_type=jnp.float32)
    db = jnp.sum(dz_m, axis=0)
    da = jnp.matmul(dz_m, w.T, preferred_element_type=jnp.float32)
    return da, dw, db


def scatter_cols(full_cols: int, idx: jnp.ndarray, dw_s: jnp.ndarray) -> jnp.ndarray:
    """Scatter skeleton columns ``dw_s [K,k]`` back into a zero ``[K,N]``."""
    out = jnp.zeros((dw_s.shape[0], full_cols), dtype=dw_s.dtype)
    return out.at[:, idx].set(dw_s)
