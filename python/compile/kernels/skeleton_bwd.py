"""L1 skeleton-gradient backward kernels (the paper's Fig. 3 hot-spot).

For a layer ``z = a @ w + b`` (conv layers reach here as im2col GEMMs), the
FedSkel *UpdateSkel* backward prunes the output-channel gradient ``dz`` to
the skeleton channels ``idx`` and performs genuinely smaller GEMMs:

    dz_s = dz[:, idx]              # [M, k]   gather, k = ceil(r * N)
    dw_s = a.T @ dz_s              # [K, k]   weight-gradient GEMM
    db_s = sum(dz_s, axis=0)       # [k]
    da   = dz_s @ w[:, idx].T      # [M, K]   gradient back-prop GEMM

Two variants are provided:

* :func:`skeleton_bwd` — the *gathered* (structured) form the paper argues
  for: channel indices are gathered once into dense buffers, then both
  GEMMs run through the Pallas tiled matmul at reduced shape. Compute
  scales with ``r``.
* :func:`masked_bwd_pallas` — the *masked* strawman (full-shape GEMMs with
  a fused 0/1 channel mask on the ``dz`` operand). Same numerics on the
  skeleton channels, but full-width FLOPs — the ablation baseline showing
  why structured > unstructured for hardware efficiency.

The ``db`` fusion trick: instead of a separate column-sum pass over
``dz_s``, we append a ones-column to ``a`` so a single GEMM yields
``[dw_s; db_s]`` stacked — one VMEM staging of ``dz_s`` serves both.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import matmul as mm


def skeleton_gather(dz: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Gather skeleton channels of ``dz [M,N]`` into dense ``[M,k]``.

    ``idx`` is a runtime i32 vector with *static* length k, so each ratio
    bucket compiles to fixed smaller GEMM shapes while the channel choice
    stays a runtime decision of the L3 coordinator.
    """
    return jnp.take(dz, idx, axis=1)


def skeleton_bwd(
    dz: jnp.ndarray,
    a: jnp.ndarray,
    w: jnp.ndarray,
    idx: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Structured-pruned backward: ``(da, dw_s, db_s)`` (see module doc).

    Both GEMMs execute in the Pallas tiled-matmul kernel at skeleton shape.
    """
    dz_s = skeleton_gather(dz, idx)  # [M, k]
    dw_s = mm.matmul_pallas(a.T, dz_s)  # [K, k]
    # db as a plain reduction — XLA fuses it into the gather's consumer.
    # (§Perf note: an earlier version fused db into the dW GEMM by
    # appending a ones-column to `a`; the concat copied the whole [M,K]
    # activation every call — O(M·K) traffic independent of the skeleton
    # size k — and cost more than the fused reduction saved.)
    db_s = jnp.sum(dz_s, axis=0)
    w_s = jnp.take(w, idx, axis=1)  # [K, k]
    da = mm.matmul_pallas(dz_s, w_s.T)  # [M, K]
    return da, dw_s, db_s


def _masked_matmul_kernel(a_ref, b_ref, mask_ref, o_ref, acc_ref, *, n_k: int):
    """acc += A_tile @ (B_tile * col_mask) — mask fused into the operand
    load so the masked variant costs full-shape FLOPs (the point of the
    ablation) but no extra memory pass."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    b_masked = b_ref[...] * mask_ref[...][None, :]
    acc_ref[...] += jnp.dot(a_ref[...], b_masked, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def masked_matmul_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    bm: int = mm.DEFAULT_BM,
    bk: int = mm.DEFAULT_BK,
    bn: int = mm.DEFAULT_BN,
) -> jnp.ndarray:
    """``a @ (b * mask[None,:])`` with the column mask fused into the Pallas
    matmul (full-shape compute; ablation baseline)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and mask.shape == (n,)
    bm, bk, bn = mm.pick_blocks(m, k, n, bm, bk, bn)
    mp, kp, np_ = mm._ceil_to(m, bm), mm._ceil_to(k, bk), mm._ceil_to(n, bn)
    a_p = mm._pad_to(a, mp, kp)
    b_p = mm._pad_to(b, kp, np_)
    mask_p = jnp.pad(mask, (0, np_ - n))
    n_k = kp // bk

    out = pl.pallas_call(
        functools.partial(_masked_matmul_kernel, n_k=n_k),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[mm.pltpu_scratch(bm, bn)],
        interpret=True,
    )(a_p, b_p, mask_p)
    return out[:m, :n]


def masked_bwd_pallas(
    dz: jnp.ndarray,
    a: jnp.ndarray,
    w: jnp.ndarray,
    mask: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full-shape masked backward (ablation): ``(da, dw, db)`` where the
    non-skeleton channels of dw/db are exactly zero and da only carries
    skeleton contributions — numerically equal to scattering
    :func:`skeleton_bwd` back to full shape."""
    dw = masked_matmul_pallas(a.T, dz, mask)  # [K, N], masked cols
    db = jnp.sum(dz * mask[None, :], axis=0)
    dz_m = dz * mask[None, :]
    da = mm.matmul_pallas(dz_m, w.T)
    return da, dw, db
