"""L1 Pallas tiled matmul — the compute hot-spot of every layer.

All dense compute in the L2 model (conv-as-im2col and FC layers, forward
and backward) funnels through :func:`matmul` / :func:`matmul_bias` here, so
the paper's structured gradient pruning shows up as *smaller matmul shapes*
flowing through this one kernel.

Hardware adaptation (DESIGN.md §6): the paper tiles Caffe CPU GEMMs; we
tile for a TPU-shaped memory hierarchy instead. BlockSpec expresses the
HBM→VMEM schedule: (bm × bk) and (bk × bn) operand tiles are staged into
VMEM and contracted on the MXU; the grid walks (M/bm, N/bn, K/bk) with the
K axis innermost so each output tile accumulates in place across K steps.
``interpret=True`` everywhere — the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU efficiency is estimated from the BlockSpec footprint
in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile caps (see pick_blocks). Sized for interpret-mode grid-step
# economy while staying within a real TPU core's VMEM when double-buffered:
# worst-case tile budget bm·bk + bk·bn + bm·bn ≈ 2048·1024 + 1024·512 +
# 2048·512 floats ≈ 14.5 MiB — the per-target BlockSpec table in DESIGN.md
# §6 shrinks these to 512/512/128 for a real MXU build.
DEFAULT_BM = 2048
DEFAULT_BK = 16384  # cap only; pick_blocks' budget sets the effective depth
DEFAULT_BN = 512


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    """One (i, j, k) grid step: acc += A[i,k] @ B[k,j]; flush at k == n_k-1.

    ``acc_ref`` is an f32 VMEM scratch accumulator so low-precision inputs
    still accumulate in f32 across the K walk (MXU-style).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def pick_blocks(m: int, k: int, n: int, bm: int, bk: int, bn: int):
    """Adaptive BlockSpec sizing (§Perf iteration 1, EXPERIMENTS.md).

    Two rules replace the original fixed 128³ tiling:

    1. **Exact-fit small dims** — a dimension smaller than the requested
       tile becomes its own block with *no* rounding. Structured pruning
       shrinks exactly these dims (the skeleton size k_l), so quantizing
       them to a tile multiple would erase the compute reduction the paper
       claims (measured: r=10% went 1.03× → ~4× after this change).
    2. **Grow blocks along big dims** — interpret-mode pallas pays a
       per-grid-step cost that dwarfs the arithmetic at LeNet sizes, so
       blocks stretch (cap 2048/1024) to cut grid steps. The tile budget
       (bm·bk + bk·bn + bm·bn floats ≈ ≤6 MiB) still fits a real TPU core's
       16 MiB VMEM with double-buffering headroom — DESIGN.md §6.
    """
    bm = min(m, bm)
    bn = min(n, bn)
    # Contraction block: spend the remaining tile budget on K. Skinny
    # GEMMs (tiny M·N, huge K — exactly the skeleton dW shape) get a deep
    # K block so the grid walk doesn't dominate; fat GEMMs keep bk small.
    budget = 8 * 1024 * 1024  # floats; ≈32 MiB of f32 tile traffic
    bk_budget = max(256, (budget - bm * bn) // max(1, bm + bn))
    bk = min(k, bk, int(bk_budget))
    return bm, bk, bn


def _pad_to(x: jnp.ndarray, m: int, n: int) -> jnp.ndarray:
    pm, pn = m - x.shape[0], n - x.shape[1]
    if pm == 0 and pn == 0:
        return x
    return jnp.pad(x, ((0, pm), (0, pn)))


def _ceil_to(x: int, b: int) -> int:
    return ((x + b - 1) // b) * b


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
) -> jnp.ndarray:
    """Tiled Pallas matmul ``a @ b`` for arbitrary (M,K)x(K,N) f32 inputs.

    Operands are zero-padded up to tile multiples (zero rows/cols contribute
    nothing to the contraction), tiled through VMEM-sized blocks, and the
    result is sliced back to (M, N).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {a.shape} @ {b.shape}"
    bm, bk, bn = pick_blocks(m, k, n, bm, bk, bn)
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    a_p = _pad_to(a, mp, kp)
    b_p = _pad_to(b, kp, np_)
    n_k = kp // bk

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pl.ScratchShape((bm, bn), jnp.float32)]
        if hasattr(pl, "ScratchShape")
        else [pltpu_scratch(bm, bn)],
        interpret=True,
    )(a_p, b_p)
    return out[:m, :n]


def pltpu_scratch(bm: int, bn: int):
    """Version-portable VMEM scratch shape (pallas moved this around)."""
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM((bm, bn), jnp.float32)
    except Exception:  # pragma: no cover - fallback for older jax
        import jax

        return jax.ShapeDtypeStruct((bm, bn), jnp.float32)


def _bias_add_kernel(x_ref, b_ref, o_ref):
    o_ref[...] = x_ref[...] + b_ref[...]


@jax.custom_vjp
def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Differentiable Pallas matmul: fwd and both bwd GEMMs run in Pallas."""
    return matmul_pallas(a, b)


def _matmul_fwd(a, b):
    return matmul_pallas(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    da = matmul_pallas(g, b.T)
    db = matmul_pallas(a.T, g)
    return da, db


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def matmul_bias(a: jnp.ndarray, b: jnp.ndarray, bias: jnp.ndarray) -> jnp.ndarray:
    """``a @ b + bias`` — matmul through Pallas, broadcast add fused by XLA."""
    return matmul(a, b) + bias[None, :]
