"""AOT compiler: lower L2 step functions to HLO text + manifest.json.

This is the single build-time entry point (``make artifacts``). It lowers
every (model × ratio-bucket) train step, per-model eval step, and the
Table-1 conv-backward probes to **HLO text** and writes
``artifacts/manifest.json`` describing each artifact's positional argument
list so the rust runtime (rust/src/runtime/) can feed Literals blind.

HLO *text* is the interchange format — NOT ``lowered.compile().serialize()``
— because jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction ids that
xla_extension 0.5.1 (what the published ``xla`` 0.1.6 crate binds) rejects;
the text parser reassigns ids. See /opt/xla-example/README.md.

Usage:
    python -m compile.aot --out-dir ../artifacts              # full set
    python -m compile.aot --out-dir ../artifacts --quick      # dev subset
    python -m compile.aot --models lenet_smnist --buckets 10,100
"""

from __future__ import annotations

import argparse
import hashlib
import json
import math
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

F32 = jnp.float32
I32 = jnp.int32


# --------------------------------------------------------------------------
# Model registry: name -> builder. Mirrors rust/src/model/spec.rs.
# --------------------------------------------------------------------------


def model_registry(resnet_width: int):
    return {
        "lenet_smnist": lambda: M.make_lenet((28, 28, 1), 10, "lenet_smnist"),
        "lenet_sfemnist": lambda: M.make_lenet((28, 28, 1), 62, "lenet_sfemnist"),
        "lenet_scifar10": lambda: M.make_lenet((32, 32, 3), 10, "lenet_scifar10"),
        "lenet_scifar100": lambda: M.make_lenet((32, 32, 3), 100, "lenet_scifar100"),
        "resnet18_scifar10": lambda: M.make_resnet(18, resnet_width, (32, 32, 3), 10, "resnet18_scifar10"),
        "resnet34_scifar10": lambda: M.make_resnet(34, resnet_width, (32, 32, 3), 10, "resnet34_scifar10"),
    }


DEFAULT_BUCKETS = {
    # lenet_smnist drives Table 1 / Table 2 / Fig 5 / MNIST column of
    # Table 3 — full bucket resolution.
    "lenet_smnist": [10, 20, 30, 40, 50, 60, 70, 80, 90, 100],
    # remaining Table 3 datasets: coarser buckets (single-core AOT budget);
    # client ratios are quantized to the nearest bucket by the coordinator.
    "lenet_sfemnist": [10, 40, 70, 100],
    "lenet_scifar10": [10, 40, 70, 100],
    "lenet_scifar100": [10, 40, 70, 100],
    "resnet18_scifar10": [10, 50, 100],
    "resnet34_scifar10": [10, 50, 100],
}

QUICK_MODELS = ["lenet_smnist"]
QUICK_BUCKETS = {"lenet_smnist": [10, 40, 100]}


def skel_sizes(model: M.ModelDef, ratio_pct: int) -> list[int]:
    """k_l = max(1, ceil(r · C_l)) per prunable layer (paper §3.2)."""
    r = ratio_pct / 100.0
    return [max(1, math.ceil(r * p.channels)) for p in model.prunable]


# --------------------------------------------------------------------------
# Lowering helpers.
# --------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def sds(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def spec_list(names_shapes_dtypes):
    return [
        {"name": n, "shape": list(s), "dtype": d}
        for (n, s, d) in names_shapes_dtypes
    ]


def lower_train(model: M.ModelDef, batch: int, ratio_pct: int):
    """Lower one (model, bucket) train step; return (hlo_text, io_spec)."""
    ks = skel_sizes(model, ratio_pct)
    h, w, c = model.input_shape
    params = [sds(p.shape) for p in model.params]
    gparams = [sds(p.shape) for p in model.params]
    x = sds((batch, h, w, c))
    y = sds((batch,), I32)
    idxs = [sds((k,), I32) for k in ks]
    lr = sds((), F32)
    mu = sds((), F32)

    step = M.make_train_step(model)
    lowered = jax.jit(step).lower(params, gparams, x, y, idxs, lr, mu)
    text = to_hlo_text(lowered)

    inputs = (
        [(f"param.{p.name}", p.shape, "f32") for p in model.params]
        + [(f"global.{p.name}", p.shape, "f32") for p in model.params]
        + [("x", (batch, h, w, c), "f32"), ("y", (batch,), "i32")]
        + [
            (f"idx.{pr.name}", (k,), "i32")
            for pr, k in zip(model.prunable, ks)
        ]
        + [("lr", (), "f32"), ("mu", (), "f32")]
    )
    outputs = (
        [(f"new.{p.name}", p.shape, "f32") for p in model.params]
        + [("loss", (), "f32")]
        + [(f"imp.{pr.name}", (pr.channels,), "f32") for pr in model.prunable]
    )
    return text, {
        "kind": "train",
        "ratio": ratio_pct,
        "batch": batch,
        "k": ks,
        "inputs": spec_list(inputs),
        "outputs": spec_list(outputs),
    }


def lower_eval(model: M.ModelDef, batch: int):
    h, w, c = model.input_shape
    params = [sds(p.shape) for p in model.params]
    x = sds((batch, h, w, c))
    step = M.make_eval_step(model)
    lowered = jax.jit(step).lower(params, x)
    text = to_hlo_text(lowered)
    inputs = [(f"param.{p.name}", p.shape, "f32") for p in model.params] + [
        ("x", (batch, h, w, c), "f32")
    ]
    outputs = [("logits", (batch, model.num_classes), "f32")]
    return text, {
        "kind": "eval",
        "batch": batch,
        "inputs": spec_list(inputs),
        "outputs": spec_list(outputs),
    }


def lower_convbwd(model: M.ModelDef, batch: int, ratio_pct: int):
    """Table 1 'Back-prop' probe: conv-layer skeleton backward only."""
    probe, convs, ks, shapes = M.make_conv_bwd_probe(model, batch, ratio_pct / 100.0)
    args = []
    for s in shapes:
        args.append(sds(s, I32 if len(s) == 1 and s[0] in ks else F32))
    # idx args are the 1-d ones at every 4th position (dz,a,w,idx)*
    args = []
    names = []
    for ci, ((m, k, n), ksz) in enumerate(zip(convs, ks)):
        args += [sds((m, n)), sds((m, k)), sds((k, n)), sds((ksz,), I32)]
        names += [
            (f"conv{ci}.dz", (m, n), "f32"),
            (f"conv{ci}.a", (m, k), "f32"),
            (f"conv{ci}.w", (k, n), "f32"),
            (f"conv{ci}.idx", (ksz,), "i32"),
        ]
    lowered = jax.jit(probe).lower(*args)
    text = to_hlo_text(lowered)
    return text, {
        "kind": "convbwd",
        "ratio": ratio_pct,
        "batch": batch,
        "k": ks,
        "gemms": [list(g) for g in convs],
        "inputs": spec_list(names),
        "outputs": spec_list([("checksum", (), "f32")]),
    }


# --------------------------------------------------------------------------
# Driver.
# --------------------------------------------------------------------------


def model_manifest_entry(model: M.ModelDef, train_batch: int, eval_batch: int):
    return {
        "input_shape": list(model.input_shape),
        "num_classes": model.num_classes,
        "train_batch": train_batch,
        "eval_batch": eval_batch,
        "num_params": model.num_params(),
        "params": [
            {"name": p.name, "shape": list(p.shape), "init": p.init}
            for p in model.params
        ],
        "prunable": [
            {
                "name": p.name,
                "channels": p.channels,
                "weight_param": p.weight_param,
                "bias_param": p.bias_param,
            }
            for p in model.prunable
        ],
        "artifacts": {},
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default=None, help="comma list; default: full set")
    ap.add_argument("--buckets", default=None, help="comma list of ratio %%, overrides per-model defaults")
    ap.add_argument("--quick", action="store_true", help="dev subset: lenet_smnist @ {10,40,100}")
    ap.add_argument("--train-batch", type=int, default=32)
    ap.add_argument("--eval-batch", type=int, default=128)
    ap.add_argument("--bench-batch", type=int, default=128,
                    help="batch for Table-1 convbwd probes (paper used 512; single-core default 128)")
    ap.add_argument("--resnet-width", type=int, default=8,
                    help="ResNet base width (paper-faithful: 64)")
    ap.add_argument("--no-convbwd", action="store_true")
    args = ap.parse_args(argv)

    registry = model_registry(args.resnet_width)
    if args.quick:
        model_names = QUICK_MODELS
        buckets_for = lambda m: QUICK_BUCKETS.get(m, [10, 100])
    else:
        model_names = (
            args.models.split(",") if args.models else list(registry.keys())
        )
        if args.buckets:
            fixed = [int(b) for b in args.buckets.split(",")]
            buckets_for = lambda m: fixed
        else:
            buckets_for = lambda m: DEFAULT_BUCKETS[m]

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {
        "version": 1,
        "jax_version": jax.__version__,
        "resnet_width": args.resnet_width,
        "models": {},
        "bench": {},
    }

    t_start = time.time()

    def emit(fname: str, text: str):
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        return hashlib.sha256(text.encode()).hexdigest()[:16]

    for mname in model_names:
        model = registry[mname]()
        entry = model_manifest_entry(model, args.train_batch, args.eval_batch)
        for r in buckets_for(mname):
            t0 = time.time()
            text, spec = lower_train(model, args.train_batch, r)
            fname = f"{mname}_train_r{r}.hlo.txt"
            spec["file"] = fname
            spec["sha256_16"] = emit(fname, text)
            entry["artifacts"][f"train_r{r}"] = spec
            print(f"[aot] {fname:44s} {len(text)/1e6:6.2f}MB  {time.time()-t0:5.1f}s", flush=True)
        t0 = time.time()
        text, spec = lower_eval(model, args.eval_batch)
        fname = f"{mname}_eval.hlo.txt"
        spec["file"] = fname
        spec["sha256_16"] = emit(fname, text)
        entry["artifacts"]["eval"] = spec
        print(f"[aot] {fname:44s} {len(text)/1e6:6.2f}MB  {time.time()-t0:5.1f}s", flush=True)
        manifest["models"][mname] = entry

    if not args.no_convbwd and "lenet_smnist" in model_names:
        model = registry["lenet_smnist"]()
        probes = {}
        for r in [10, 20, 30, 40, 100]:
            t0 = time.time()
            text, spec = lower_convbwd(model, args.bench_batch, r)
            fname = f"convbwd_lenet_r{r}.hlo.txt"
            spec["file"] = fname
            spec["sha256_16"] = emit(fname, text)
            probes[f"r{r}"] = spec
            print(f"[aot] {fname:44s} {len(text)/1e6:6.2f}MB  {time.time()-t0:5.1f}s", flush=True)
        manifest["bench"]["convbwd_lenet"] = probes

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote manifest.json — total {time.time()-t_start:.0f}s")


if __name__ == "__main__":
    main()
