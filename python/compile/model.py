"""L2 — JAX model definitions with FedSkel skeleton-gradient updates.

Everything here is build-time only: ``aot.py`` lowers the jitted step
functions to HLO text once, and the L3 rust coordinator executes the
artifacts via PJRT. No Python on the training path.

Core mechanism — the *skeleton layer* (:func:`skel_dense`): forward is a
full-width GEMM (paper §3.1: forward is never pruned); backward prunes the
output-channel gradient ``dZ`` to the skeleton channels ``idx`` and runs
genuinely smaller GEMMs through the L1 Pallas kernels
(:mod:`compile.kernels.skeleton_bwd`). ``idx`` has *static length*
``k = ceil(r · C)`` per ratio-bucket artifact, so each bucket compiles to
fixed reduced shapes, while the channel *choice* is a runtime input decided
by the L3 coordinator at SetSkel time.

Conv layers lower to im2col + the same skeleton GEMM, so output-channel
pruning of a conv is column pruning of its GEMM — exactly the structured
pruning of Fig. 3.

Models:
  * LeNet-5 (paper's MNIST/FEMNIST/CIFAR LeNet), input geometry generic.
  * ResNet-18/34, CIFAR-style, GroupNorm instead of BatchNorm (FL-friendly:
    no cross-client running statistics; documented in DESIGN.md §3).

The single :func:`make_train_step` serves every method in the paper's
evaluation: FedSkel (idx ⊂ channels, mu=0), FedAvg (identity idx, mu=0),
FedMTL-style local training (identity idx, mu>0 prox-to-global), LG-FedAvg
(identity idx; the layer split is an aggregation-side concern in L3).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import matmul as mm
from .kernels import skeleton_bwd as sb

Array = jnp.ndarray


# --------------------------------------------------------------------------
# Skeleton layer: full forward, structurally pruned backward.
# --------------------------------------------------------------------------


@jax.custom_vjp
def skel_dense(a: Array, w: Array, b: Array, idx: Array) -> Array:
    """``a @ w + b`` with skeleton-pruned backward (see module docstring).

    a: [M, K], w: [K, N], b: [N], idx: i32[k] skeleton channel indices.
    """
    return mm.matmul_bias(a, w, b)


def _skel_dense_fwd(a, w, b, idx):
    return mm.matmul_bias(a, w, b), (a, w, idx)


def _skel_dense_bwd(res, dz):
    a, w, idx = res
    da, dw_s, db_s = sb.skeleton_bwd(dz, a, w, idx)
    # Scatter the skeleton columns back to full parameter shape so the SGD
    # update is a plain axpy; non-skeleton gradients are exactly zero.
    dw = jnp.zeros_like(w).at[:, idx].set(dw_s)
    db = jnp.zeros((w.shape[1],), dtype=dz.dtype).at[idx].set(db_s)
    return da, dw, db, None


skel_dense.defvjp(_skel_dense_fwd, _skel_dense_bwd)


def dense_infer(a: Array, w: Array, b: Array) -> Array:
    """Inference-path dense layer (no vjp machinery, same Pallas matmul)."""
    return mm.matmul_bias(a, w, b)


# --------------------------------------------------------------------------
# Conv as im2col + skeleton GEMM.
# --------------------------------------------------------------------------


def _im2col(x: Array, kh: int, kw: int, stride: int, padding: str) -> Array:
    """Extract patches: x [B,H,W,C] -> [B, OH, OW, C*KH*KW].

    conv_general_dilated_patches emits *channel-major* patch features
    (C slowest, then KH, KW), so the matching weight GEMM view is
    ``w[KH,KW,C,Cout] -> transpose(2,0,1,3) -> [(C*KH*KW), Cout]``.
    """
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return patches


def conv2d(
    x: Array,
    w: Array,
    b: Array,
    idx: Array,
    *,
    stride: int = 1,
    padding: str = "VALID",
    skel: bool = True,
) -> Array:
    """2-D convolution via im2col + (skeleton) GEMM.

    x: [B,H,W,Cin], w: [KH,KW,Cin,Cout], b: [Cout]. Output-channel pruning
    of the conv == column pruning of the GEMM (paper Fig. 3).
    """
    kh, kw, cin, cout = w.shape
    patches = _im2col(x, kh, kw, stride, padding)  # [B,OH,OW,KH*KW*Cin]
    bsz, oh, ow, pdim = patches.shape
    a2 = patches.reshape(bsz * oh * ow, pdim)
    w2 = jnp.transpose(w, (2, 0, 1, 3)).reshape(cin * kh * kw, cout)
    if skel:
        z2 = skel_dense(a2, w2, b, idx)
    else:
        z2 = dense_infer(a2, w2, b)
    return z2.reshape(bsz, oh, ow, cout)


def avg_pool2(x: Array) -> Array:
    """2x2 average pooling, stride 2 (LeNet's subsampling)."""
    b, h, w, c = x.shape
    x = x.reshape(b, h // 2, 2, w // 2, 2, c)
    return x.mean(axis=(2, 4))


def global_avg_pool(x: Array) -> Array:
    return x.mean(axis=(1, 2))


def relu(x: Array) -> Array:
    return jnp.maximum(x, 0.0)


def group_norm(x: Array, scale: Array, shift: Array, groups: int) -> Array:
    """GroupNorm over [B,H,W,C] — the FL-friendly BatchNorm substitute."""
    b, h, w, c = x.shape
    g = groups
    xg = x.reshape(b, h, w, g, c // g)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + 1e-5)
    x = xg.reshape(b, h, w, c)
    return x * scale[None, None, None, :] + shift[None, None, None, :]


def channel_importance(a: Array) -> Array:
    """Paper Eq. 2: M_i = mean |A_i| over batch (+ spatial) dims."""
    if a.ndim == 4:
        return jnp.mean(jnp.abs(a), axis=(0, 1, 2))
    return jnp.mean(jnp.abs(a), axis=0)


# --------------------------------------------------------------------------
# Model definitions.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: Tuple[int, ...]
    init: str  # "he" | "glorot" | "zeros" | "ones"


@dataclasses.dataclass(frozen=True)
class PrunableSpec:
    """One skeleton-prunable layer: its channel count and which flat param
    indices hold its (weight, bias)."""

    name: str
    channels: int
    weight_param: int
    bias_param: int


@dataclasses.dataclass(frozen=True)
class ModelDef:
    name: str
    input_shape: Tuple[int, int, int]  # H, W, C
    num_classes: int
    params: Tuple[ParamSpec, ...]
    prunable: Tuple[PrunableSpec, ...]
    # forward(params, x, idxs, skel) -> (logits, importances)
    forward: Callable[[List[Array], Array, List[Array], bool], Tuple[Array, List[Array]]]

    def num_params(self) -> int:
        return sum(math.prod(p.shape) for p in self.params)


class _Cursor:
    """Sequential reader over the flat param list, keeping fwd code tidy."""

    def __init__(self, params: Sequence[Array]):
        self.params = params
        self.i = 0

    def take(self, n: int = 1):
        out = self.params[self.i : self.i + n]
        self.i += n
        return out[0] if n == 1 else out


def make_lenet(
    input_shape: Tuple[int, int, int] = (28, 28, 1),
    num_classes: int = 10,
    name: str = "lenet",
) -> ModelDef:
    """LeNet-5 (conv5x5(6) → pool → conv5x5(16) → pool → 120 → 84 → C).

    Prunable: conv1, conv2, fc1, fc2 output channels — the paper's
    skeleton-selection targets. The classifier head (fc3) is never pruned.
    """
    h, w, cin = input_shape
    h1, w1 = h - 4, w - 4  # conv1 VALID 5x5
    h1p, w1p = h1 // 2, w1 // 2
    h2, w2 = h1p - 4, w1p - 4
    h2p, w2p = h2 // 2, w2 // 2
    flat = h2p * w2p * 16

    params = (
        ParamSpec("conv1.w", (5, 5, cin, 6), "he"),
        ParamSpec("conv1.b", (6,), "zeros"),
        ParamSpec("conv2.w", (5, 5, 6, 16), "he"),
        ParamSpec("conv2.b", (16,), "zeros"),
        ParamSpec("fc1.w", (flat, 120), "he"),
        ParamSpec("fc1.b", (120,), "zeros"),
        ParamSpec("fc2.w", (120, 84), "he"),
        ParamSpec("fc2.b", (84,), "zeros"),
        ParamSpec("fc3.w", (84, num_classes), "glorot"),
        ParamSpec("fc3.b", (num_classes,), "zeros"),
    )
    prunable = (
        PrunableSpec("conv1", 6, 0, 1),
        PrunableSpec("conv2", 16, 2, 3),
        PrunableSpec("fc1", 120, 4, 5),
        PrunableSpec("fc2", 84, 6, 7),
    )

    def forward(ps, x, idxs, skel=True):
        c = _Cursor(ps)
        w1_, b1 = c.take(2)
        w2_, b2 = c.take(2)
        w3, b3 = c.take(2)
        w4, b4 = c.take(2)
        w5, b5 = c.take(2)
        imps = []
        a = avg_pool2(relu(conv2d(x, w1_, b1, idxs[0], skel=skel)))
        imps.append(channel_importance(a))
        a = avg_pool2(relu(conv2d(a, w2_, b2, idxs[1], skel=skel)))
        imps.append(channel_importance(a))
        a = a.reshape(a.shape[0], -1)
        a = relu(skel_dense(a, w3, b3, idxs[2]) if skel else dense_infer(a, w3, b3))
        imps.append(channel_importance(a))
        a = relu(skel_dense(a, w4, b4, idxs[3]) if skel else dense_infer(a, w4, b4))
        imps.append(channel_importance(a))
        logits = dense_infer(a, w5, b5)
        return logits, imps

    return ModelDef(name, input_shape, num_classes, params, prunable, forward)


def _gn_groups(c: int) -> int:
    g = min(8, c)
    while c % g != 0:
        g -= 1
    return g


def make_resnet(
    depth: int = 18,
    width: int = 16,
    input_shape: Tuple[int, int, int] = (32, 32, 3),
    num_classes: int = 10,
    name: str | None = None,
) -> ModelDef:
    """CIFAR-style ResNet-{18,34} with basic blocks and GroupNorm.

    Stage widths (w, 2w, 4w, 8w); paper-faithful width is w=64, the default
    w=16 keeps CPU interpret-mode budgets sane (DESIGN.md §3 scale knob).
    Prunable: the *first* conv of every basic block — its output channels
    are block-internal, so pruning them never conflicts with the residual
    addition (standard structured-pruning practice).
    """
    blocks_per_stage = {18: (2, 2, 2, 2), 34: (3, 4, 6, 3)}[depth]
    widths = (width, 2 * width, 4 * width, 8 * width)
    h, w_, cin = input_shape
    name = name or f"resnet{depth}"

    specs: List[ParamSpec] = []
    prunable: List[PrunableSpec] = []

    def add(name_, shape, init):
        specs.append(ParamSpec(name_, tuple(shape), init))
        return len(specs) - 1

    # Stem.
    add("stem.w", (3, 3, cin, widths[0]), "he")
    add("stem.b", (widths[0],), "zeros")
    add("stem.gn.s", (widths[0],), "ones")
    add("stem.gn.t", (widths[0],), "zeros")

    # Blocks.
    block_layout = []  # (stage, blk, stride, cin, cout, param indices dict)
    c_in = widths[0]
    for s, (nblk, cout) in enumerate(zip(blocks_per_stage, widths)):
        for b in range(nblk):
            stride = 2 if (s > 0 and b == 0) else 1
            pn = f"s{s}b{b}"
            iw1 = add(f"{pn}.conv1.w", (3, 3, c_in, cout), "he")
            ib1 = add(f"{pn}.conv1.b", (cout,), "zeros")
            add(f"{pn}.gn1.s", (cout,), "ones")
            add(f"{pn}.gn1.t", (cout,), "zeros")
            add(f"{pn}.conv2.w", (3, 3, cout, cout), "he")
            add(f"{pn}.conv2.b", (cout,), "zeros")
            add(f"{pn}.gn2.s", (cout,), "ones")
            add(f"{pn}.gn2.t", (cout,), "zeros")
            if stride != 1 or c_in != cout:
                add(f"{pn}.down.w", (1, 1, c_in, cout), "he")
                add(f"{pn}.down.b", (cout,), "zeros")
                has_down = True
            else:
                has_down = False
            prunable.append(PrunableSpec(f"{pn}.conv1", cout, iw1, ib1))
            block_layout.append((s, b, stride, c_in, cout, has_down))
            c_in = cout

    add("fc.w", (widths[-1], num_classes), "glorot")
    add("fc.b", (num_classes,), "zeros")

    def forward(ps, x, idxs, skel=True):
        c = _Cursor(ps)
        imps = []
        # Stem (not prunable: its channels feed every residual path).
        wst, bst, gs, gt = c.take(4)
        a = conv2d(x, wst, bst, jnp.arange(widths[0], dtype=jnp.int32),
                   stride=1, padding="SAME", skel=False)
        a = relu(group_norm(a, gs, gt, _gn_groups(widths[0])))
        for li, (s, b, stride, ci, co, has_down) in enumerate(block_layout):
            w1_, b1, g1s, g1t, w2_, b2, g2s, g2t = c.take(8)
            shortcut = a
            h1 = conv2d(a, w1_, b1, idxs[li], stride=stride, padding="SAME", skel=skel)
            h1 = relu(group_norm(h1, g1s, g1t, _gn_groups(co)))
            imps.append(channel_importance(h1))
            h2 = conv2d(h1, w2_, b2, jnp.arange(co, dtype=jnp.int32),
                        stride=1, padding="SAME", skel=False)
            h2 = group_norm(h2, g2s, g2t, _gn_groups(co))
            if has_down:
                wd, bd = c.take(2)
                shortcut = conv2d(shortcut, wd, bd,
                                  jnp.arange(co, dtype=jnp.int32),
                                  stride=stride, padding="SAME", skel=False)
            a = relu(h2 + shortcut)
        wf, bf = c.take(2)
        a = global_avg_pool(a)
        logits = dense_infer(a, wf, bf)
        return logits, imps

    return ModelDef(name, input_shape, num_classes, tuple(specs), tuple(prunable), forward)


# --------------------------------------------------------------------------
# Init / loss / step functions.
# --------------------------------------------------------------------------


def init_params(model: ModelDef, seed: int = 0) -> List[Array]:
    """He/Glorot init — mirrored exactly by the rust host-side initializer
    (rust/src/model/init.rs); pytest cross-checks the statistics."""
    key = jax.random.PRNGKey(seed)
    out = []
    for spec in model.params:
        key, sub = jax.random.split(key)
        shape = spec.shape
        if spec.init == "zeros":
            out.append(jnp.zeros(shape, jnp.float32))
        elif spec.init == "ones":
            out.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = math.prod(shape[:-1]) if len(shape) > 1 else shape[0]
            fan_out = shape[-1]
            if spec.init == "he":
                std = math.sqrt(2.0 / fan_in)
            else:  # glorot
                std = math.sqrt(2.0 / (fan_in + fan_out))
            out.append(std * jax.random.normal(sub, shape, jnp.float32))
    return out


def softmax_cross_entropy(logits: Array, labels: Array) -> Array:
    """Mean CE over the batch; labels are i32 class ids."""
    logz = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logz, labels[:, None], axis=-1)[:, 0]
    return -jnp.mean(ll)


def make_train_step(model: ModelDef):
    """Build the jittable local-SGD step.

    Signature (all leading lists flattened positionally by aot.py):
        train_step(params, global_params, x, y, idxs, lr, mu)
          -> (new_params, loss, importances)

    * ``params``        — client's current weights.
    * ``global_params`` — server weights for the FedProx-style term
                          ``mu/2 · Σ‖p − g‖²`` (mu=0 disables; serves the
                          FedMTL baseline and FedProx ablation).
    * ``idxs``          — per-prunable-layer skeleton indices (i32, static
                          length per ratio bucket).
    * importances       — per-prunable-layer mean |A| (Eq. 2), accumulated
                          by the L3 coordinator during SetSkel rounds.
    """

    def train_step(params, global_params, x, y, idxs, lr, mu):
        def loss_fn(ps):
            logits, imps = model.forward(ps, x, idxs, True)
            loss = softmax_cross_entropy(logits, y)
            prox = 0.5 * mu * sum(
                jnp.vdot(p - g, p - g) for p, g in zip(ps, global_params)
            )
            return loss + prox, (imps, loss)

        grads, (imps, data_loss) = jax.grad(loss_fn, has_aux=True)(params)
        new_params = [p - lr * g for p, g in zip(params, grads)]
        return new_params, data_loss, imps

    return train_step


def make_eval_step(model: ModelDef):
    """Jittable inference: (params, x) -> logits (no vjp machinery)."""

    full_idxs = [
        jnp.arange(p.channels, dtype=jnp.int32) for p in model.prunable
    ]

    def eval_step(params, x):
        logits, _ = model.forward(params, x, full_idxs, False)
        return logits

    return eval_step


def make_conv_bwd_probe(model: ModelDef, batch: int, ratio: float):
    """Standalone conv-layer backward pass at skeleton shapes — the Table 1
    'Back-prop' microbench artifact. Runs skeleton_bwd for every conv-GEMM
    of the model at the given ratio; returns a checksum so nothing is DCE'd.
    """
    convs = []  # (M, K, N) GEMM shapes of each prunable conv at `batch`
    h, w, cin = model.input_shape
    if model.name.startswith("lenet"):
        h1, w1 = (h - 4) // 2, (w - 4) // 2
        convs = [
            (batch * (h - 4) * (w - 4), 25 * cin, 6),
            (batch * (h1 - 4) * (w1 - 4), 25 * 6, 16),
        ]
    else:
        # ResNet: one probe GEMM per prunable block conv at its fmap size.
        raise NotImplementedError("conv bwd probe is a LeNet (Table 1) bench")

    ks = [max(1, math.ceil(ratio * n)) for (_, _, n) in convs]

    def probe(*args):
        # args: for each conv: dz [M,N], a [M,K], w [K,N], idx [k]
        acc = jnp.float32(0.0)
        i = 0
        for (m, kk, n), k_sz in zip(convs, ks):
            dz, a, w_, idx = args[i : i + 4]
            i += 4
            da, dw_s, db_s = sb.skeleton_bwd(dz, a, w_, idx)
            acc = acc + jnp.sum(da) + jnp.sum(dw_s) + jnp.sum(db_s)
        return acc

    shapes = []
    for (m, kk, n), k_sz in zip(convs, ks):
        shapes += [(m, n), (m, kk), (kk, n), (k_sz,)]
    return probe, convs, ks, shapes
