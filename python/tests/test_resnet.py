"""ResNet-specific L2 tests: block structure, GroupNorm, residual paths,
skeleton semantics on block-internal convs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def r18():
    return M.make_resnet(18, width=4)


@pytest.fixture(scope="module")
def r18_params(r18):
    return M.init_params(r18, seed=2)


def full_idxs(m):
    return [jnp.arange(p.channels, dtype=jnp.int32) for p in m.prunable]


def test_depth_34_block_count():
    m = M.make_resnet(34, width=4)
    # 3+4+6+3 basic blocks, one prunable conv each
    assert len(m.prunable) == 16
    # stage widths double: 4, 8, 16, 32
    chans = sorted({p.channels for p in m.prunable})
    assert chans == [4, 8, 16, 32]


def test_param_count_scales_with_width():
    small = M.make_resnet(18, width=4).num_params()
    big = M.make_resnet(18, width=8).num_params()
    # params scale ~quadratically in width for conv-dominated nets
    assert 3.0 < big / small < 4.5


def test_group_norm_normalizes():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 4, 4, 8)).astype(np.float32)) * 5 + 3
    out = M.group_norm(x, jnp.ones(8), jnp.zeros(8), groups=4)
    # per-sample, per-group stats ~ (0, 1)
    g = out.reshape(2, 4, 4, 4, 2)
    mean = np.asarray(g.mean(axis=(1, 2, 4)))
    std = np.asarray(g.std(axis=(1, 2, 4)))
    assert np.all(np.abs(mean) < 1e-2)
    assert np.all(np.abs(std - 1.0) < 1e-2)


def test_group_norm_scale_shift():
    x = jnp.asarray(np.random.default_rng(1).standard_normal((1, 2, 2, 4)).astype(np.float32))
    out = M.group_norm(x, 2.0 * jnp.ones(4), 3.0 * jnp.ones(4), groups=2)
    base = M.group_norm(x, jnp.ones(4), jnp.zeros(4), groups=2)
    np.testing.assert_allclose(out, base * 2.0 + 3.0, atol=1e-5)


def test_gn_groups_divides():
    assert M._gn_groups(8) == 8
    assert M._gn_groups(6) == 6
    assert M._gn_groups(7) == 7
    assert M._gn_groups(32) == 8
    for c in range(1, 64):
        assert c % M._gn_groups(c) == 0


def test_residual_identity_at_zero_weights(r18):
    """Zeroing a block's conv weights must make it a pure skip (+GN shift),
    pinning that the residual wiring is correct."""
    m = r18
    ps = M.init_params(m, 3)
    # zero every block conv + gn scale so block output == shortcut
    zeroed = list(ps)
    spec_names = [p.name for p in m.params]
    for i, name in enumerate(spec_names):
        if ".conv" in name or ".gn" in name:
            zeroed[i] = jnp.zeros_like(ps[i])
    x = jnp.asarray(np.random.default_rng(2).standard_normal((1, 32, 32, 3)).astype(np.float32))
    logits, _ = m.forward(zeroed, x, full_idxs(m), False)
    # stem also zeroed -> everything collapses to fc bias
    np.testing.assert_allclose(np.asarray(logits)[0], np.asarray(ps[-1]), atol=1e-4)


def test_downsample_blocks_have_projection(r18):
    names = [p.name for p in r18.params]
    # stage transitions (s1b0, s2b0, s3b0) need 1x1 downsample projections
    for s in [1, 2, 3]:
        assert f"s{s}b0.down.w" in names
    assert "s0b0.down.w" not in names


def test_eval_equals_train_forward(r18, r18_params):
    x = jnp.asarray(np.random.default_rng(3).standard_normal((2, 32, 32, 3)).astype(np.float32))
    ev = M.make_eval_step(r18)
    lg_eval = ev(r18_params, x)
    lg_train, _ = r18.forward(r18_params, x, full_idxs(r18), True)
    np.testing.assert_allclose(lg_eval, lg_train, atol=2e-3, rtol=1e-2)


def test_importance_counts_match_prunable(r18, r18_params):
    x = jnp.asarray(np.random.default_rng(4).standard_normal((2, 32, 32, 3)).astype(np.float32))
    y = jnp.asarray([0, 1], dtype=jnp.int32)
    step = M.make_train_step(r18)
    _, _, imps = step(
        r18_params, r18_params, x, y, full_idxs(r18), jnp.float32(0.0), jnp.float32(0.0)
    )
    assert len(imps) == len(r18.prunable)
    for imp, pr in zip(imps, r18.prunable):
        assert imp.shape == (pr.channels,)
        assert bool(jnp.all(imp >= 0))
