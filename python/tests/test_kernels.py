"""L1 correctness: Pallas kernels vs the pure-jnp oracle (kernels/ref.py).

This is the core numeric signal for the whole stack — everything the rust
runtime executes lowers through these kernels. Hypothesis sweeps randomized
shapes/ratios; fixed cases pin the shapes the artifacts actually use.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as mm
from compile.kernels import ref
from compile.kernels import skeleton_bwd as sb

ATOL = 2e-4
RTOL = 2e-4


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


def assert_close(a, b, msg=""):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=ATOL, rtol=RTOL, err_msg=msg)


# ----------------------------------------------------------------- matmul


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),
        (2, 3, 4),
        (8, 8, 8),
        (32, 25, 6),        # lenet conv1 GEMM (per-pixel rows)
        (128, 150, 16),     # lenet conv2 GEMM
        (32, 256, 120),     # lenet fc1
        (100, 129, 77),     # deliberately tile-unaligned
        (512, 64, 3),
    ],
)
def test_matmul_fixed_shapes(m, k, n):
    rng = np.random.default_rng(m * 10007 + k * 101 + n)
    a, b = rand(rng, m, k), rand(rng, k, n)
    assert_close(mm.matmul_pallas(a, b), ref.matmul(a, b))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 160),
    k=st.integers(1, 160),
    n=st.integers(1, 160),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis_shapes(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, m, k), rand(rng, k, n)
    assert_close(mm.matmul_pallas(a, b), ref.matmul(a, b), f"shape {(m,k,n)}")


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 32, 128]),
    bk=st.sampled_from([8, 32, 128]),
    bn=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_block_size_invariance(bm, bk, bn, seed):
    """Result must not depend on the BlockSpec tiling choice."""
    rng = np.random.default_rng(seed)
    a, b = rand(rng, 48, 70), rand(rng, 70, 36)
    out = mm.matmul_pallas(a, b, bm=bm, bk=bk, bn=bn)
    assert_close(out, ref.matmul(a, b), f"blocks {(bm,bk,bn)}")


def test_matmul_zero_and_identity():
    rng = np.random.default_rng(0)
    a = rand(rng, 17, 23)
    z = jnp.zeros((23, 9), jnp.float32)
    assert_close(mm.matmul_pallas(a, z), jnp.zeros((17, 9)))
    eye = jnp.eye(23, dtype=jnp.float32)
    assert_close(mm.matmul_pallas(a, eye), a)


def test_matmul_vjp_matches_xla_grad():
    rng = np.random.default_rng(7)
    a, b = rand(rng, 12, 9), rand(rng, 9, 14)

    def f_pallas(a, b):
        return jnp.sum(jnp.sin(mm.matmul(a, b)))

    def f_ref(a, b):
        return jnp.sum(jnp.sin(ref.matmul(a, b)))

    ga, gb = jax.grad(f_pallas, argnums=(0, 1))(a, b)
    ra, rb = jax.grad(f_ref, argnums=(0, 1))(a, b)
    assert_close(ga, ra)
    assert_close(gb, rb)


def test_matmul_bias():
    rng = np.random.default_rng(3)
    a, b, bias = rand(rng, 20, 30), rand(rng, 30, 11), rand(rng, 11)
    assert_close(mm.matmul_bias(a, b, bias), ref.matmul_bias(a, b, bias))


# ---------------------------------------------------------- skeleton bwd


def _skel_case(rng, m, k, n, ksz):
    dz, a, w = rand(rng, m, n), rand(rng, m, k), rand(rng, k, n)
    idx = jnp.asarray(
        np.sort(rng.choice(n, size=ksz, replace=False)).astype(np.int32)
    )
    return dz, a, w, idx


@pytest.mark.parametrize(
    "m,k,n,ksz",
    [
        (4, 3, 5, 1),
        (64, 37, 20, 7),
        (128, 150, 16, 2),   # lenet conv2 @ r~10%
        (128, 150, 16, 16),  # identity skeleton == full bwd
        (32, 256, 120, 12),  # lenet fc1 @ r=10%
    ],
)
def test_skeleton_bwd_fixed(m, k, n, ksz):
    rng = np.random.default_rng(m + k + n + ksz)
    dz, a, w, idx = _skel_case(rng, m, k, n, ksz)
    da, dws, dbs = sb.skeleton_bwd(dz, a, w, idx)
    rda, rdws, rdbs = ref.skeleton_bwd(dz, a, w, idx)
    assert_close(da, rda)
    assert_close(dws, rdws)
    assert_close(dbs, rdbs)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(2, 64),
    frac=st.floats(0.05, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_skeleton_bwd_hypothesis(m, k, n, frac, seed):
    rng = np.random.default_rng(seed)
    ksz = max(1, int(frac * n))
    dz, a, w, idx = _skel_case(rng, m, k, n, ksz)
    da, dws, dbs = sb.skeleton_bwd(dz, a, w, idx)
    rda, rdws, rdbs = ref.skeleton_bwd(dz, a, w, idx)
    assert_close(da, rda, f"{(m,k,n,ksz)}")
    assert_close(dws, rdws)
    assert_close(dbs, rdbs)


def test_skeleton_full_identity_equals_dense_bwd():
    """idx = arange(N) must reproduce the unpruned backward exactly."""
    rng = np.random.default_rng(11)
    m, k, n = 40, 21, 13
    dz, a, w = rand(rng, m, n), rand(rng, m, k), rand(rng, k, n)
    idx = jnp.arange(n, dtype=jnp.int32)
    da, dws, dbs = sb.skeleton_bwd(dz, a, w, idx)
    assert_close(da, ref.matmul(dz, w.T))
    assert_close(dws, ref.matmul(a.T, dz))
    assert_close(dbs, jnp.sum(dz, axis=0))


def test_gathered_equals_masked():
    """Structured gather+scatter must equal the masked full-shape form —
    the invariant that makes the compute-reduction a pure optimization."""
    rng = np.random.default_rng(13)
    m, k, n, ksz = 48, 31, 24, 6
    dz, a, w, idx = _skel_case(rng, m, k, n, ksz)
    mask = jnp.zeros(n, jnp.float32).at[idx].set(1.0)

    da_g, dws, dbs = sb.skeleton_bwd(dz, a, w, idx)
    dw_g = ref.scatter_cols(n, idx, dws)
    db_g = jnp.zeros(n, jnp.float32).at[idx].set(dbs)

    da_m, dw_m, db_m = sb.masked_bwd_pallas(dz, a, w, mask)
    assert_close(da_g, da_m)
    assert_close(dw_g, dw_m)
    assert_close(db_g, db_m)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 64),
    n=st.integers(2, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_bwd_hypothesis(m, k, n, seed):
    rng = np.random.default_rng(seed)
    dz, a, w = rand(rng, m, n), rand(rng, m, k), rand(rng, k, n)
    mask = jnp.asarray((rng.random(n) < 0.5).astype(np.float32))
    da, dw, db = sb.masked_bwd_pallas(dz, a, w, mask)
    rda, rdw, rdb = ref.masked_bwd(dz, a, w, mask)
    assert_close(da, rda)
    assert_close(dw, rdw)
    assert_close(db, rdb)


def test_skeleton_gather_is_dense_take():
    rng = np.random.default_rng(17)
    dz = rand(rng, 10, 12)
    idx = jnp.asarray([0, 5, 11], dtype=jnp.int32)
    out = sb.skeleton_gather(dz, idx)
    assert out.shape == (10, 3)
    assert_close(out, jnp.take(dz, idx, axis=1))
