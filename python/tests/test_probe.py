"""Table-1 probe correctness: the convbwd bench artifact must compute the
same skeleton backward as the oracle — otherwise the speedup bench would
be timing garbage."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model as M
from compile.kernels import ref


def test_convbwd_probe_checksum_matches_oracle():
    m = M.make_lenet((28, 28, 1), 10, "lenet_smnist")
    probe, convs, ks, shapes = M.make_conv_bwd_probe(m, batch=2, ratio=0.3)
    rng = np.random.default_rng(0)

    args = []
    expected = 0.0
    for (mm_, kk, nn), ksz in zip(convs, ks):
        dz = rng.standard_normal((mm_, nn)).astype(np.float32)
        a = rng.standard_normal((mm_, kk)).astype(np.float32)
        w = rng.standard_normal((kk, nn)).astype(np.float32)
        idx = np.sort(rng.choice(nn, size=ksz, replace=False)).astype(np.int32)
        args += [jnp.asarray(dz), jnp.asarray(a), jnp.asarray(w), jnp.asarray(idx)]
        da, dws, dbs = ref.skeleton_bwd(jnp.asarray(dz), jnp.asarray(a), jnp.asarray(w), jnp.asarray(idx))
        expected += float(jnp.sum(da) + jnp.sum(dws) + jnp.sum(dbs))

    got = float(jax.jit(probe)(*args))
    np.testing.assert_allclose(got, expected, rtol=1e-3)


def test_convbwd_probe_shapes_scale_with_ratio():
    m = M.make_lenet((28, 28, 1), 10, "lenet_smnist")
    _, convs10, ks10, _ = M.make_conv_bwd_probe(m, batch=4, ratio=0.1)
    _, convs100, ks100, _ = M.make_conv_bwd_probe(m, batch=4, ratio=1.0)
    assert convs10 == convs100  # GEMM frames identical
    assert ks100 == [6, 16]
    assert ks10 == [1, 2]


def test_probe_artifact_lowering_inputs_alternate_dtypes():
    m = M.make_lenet((28, 28, 1), 10, "lenet_smnist")
    _, spec = aot.lower_convbwd(m, batch=2, ratio_pct=50)
    dtypes = [i["dtype"] for i in spec["inputs"]]
    # (dz, a, w, idx) per conv: f32 f32 f32 i32
    assert dtypes == ["f32", "f32", "f32", "i32"] * 2
