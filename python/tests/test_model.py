"""L2 correctness: model shapes, skeleton-gradient semantics, convergence.

Verifies the FedSkel mechanism end-to-end at the JAX level:
  * forward logits match a pure-jnp (no-Pallas) replica of the network,
  * backward with identity skeleton == unpruned training,
  * pruned backward updates exactly the skeleton channels (paper Fig. 3),
  * the FedProx term (mu) penalizes drift from global params,
  * importance outputs implement Eq. 2,
  * a few SGD steps reduce the loss on a small synthetic problem.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

ATOL = 5e-4


def full_idxs(model):
    return [jnp.arange(p.channels, dtype=jnp.int32) for p in model.prunable]


def make_batch(model, n, seed=0):
    rng = np.random.default_rng(seed)
    h, w, c = model.input_shape
    x = jnp.asarray(rng.standard_normal((n, h, w, c), dtype=np.float32))
    y = jnp.asarray(rng.integers(0, model.num_classes, n).astype(np.int32))
    return x, y


@pytest.fixture(scope="module")
def lenet():
    return M.make_lenet((28, 28, 1), 10)


@pytest.fixture(scope="module")
def lenet_params(lenet):
    return M.init_params(lenet, seed=1)


# ------------------------------------------------------------- structure


def test_lenet_param_inventory(lenet):
    assert len(lenet.params) == 10
    assert lenet.num_params() == 44426
    assert [p.name for p in lenet.prunable] == ["conv1", "conv2", "fc1", "fc2"]
    assert [p.channels for p in lenet.prunable] == [6, 16, 120, 84]


def test_lenet_geometry_32x32():
    m = M.make_lenet((32, 32, 3), 100)
    # classic LeNet geometry: 32->28->14->10->5, flat = 16*25 = 400
    assert m.params[4].shape == (400, 120)
    assert m.params[8].shape == (84, 100)


@pytest.mark.parametrize("depth,blocks", [(18, 8), (34, 16)])
def test_resnet_structure(depth, blocks):
    m = M.make_resnet(depth, width=4)
    assert len(m.prunable) == blocks
    # stage widths w,2w,4w,8w
    assert m.prunable[0].channels == 4
    assert m.prunable[-1].channels == 32


def test_resnet_forward_shapes():
    m = M.make_resnet(18, width=4)
    ps = M.init_params(m, 0)
    x, _ = make_batch(m, 2)
    logits, imps = m.forward(ps, x, full_idxs(m), False)
    assert logits.shape == (2, 10)
    assert len(imps) == 0 or len(imps) == len(m.prunable)  # eval path skips


def test_init_statistics(lenet, lenet_params):
    """He init: std ≈ sqrt(2/fan_in); biases zero. The rust initializer
    mirrors this scheme (cross-checked by rust tests)."""
    w1 = np.asarray(lenet_params[0])
    assert abs(w1.std() - np.sqrt(2.0 / 25)) < 0.05
    assert np.all(np.asarray(lenet_params[1]) == 0)


# ------------------------------------------------ forward vs pure-jnp ref


def _lenet_ref_forward(params, x):
    """No-Pallas replica of LeNet forward for cross-checking."""

    def conv(x, w, b):
        z = jax.lax.conv_general_dilated(
            x, w, (1, 1), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        return z + b[None, None, None, :]

    a = M.avg_pool2(jnp.maximum(conv(x, params[0], params[1]), 0))
    a = M.avg_pool2(jnp.maximum(conv(a, params[2], params[3]), 0))
    a = a.reshape(a.shape[0], -1)
    a = jnp.maximum(a @ params[4] + params[5], 0)
    a = jnp.maximum(a @ params[6] + params[7], 0)
    return a @ params[8] + params[9]


def test_lenet_forward_matches_lax_conv(lenet, lenet_params):
    x, _ = make_batch(lenet, 4, seed=2)
    logits, _ = lenet.forward(lenet_params, x, full_idxs(lenet), True)
    ref_logits = _lenet_ref_forward(lenet_params, x)
    np.testing.assert_allclose(logits, ref_logits, atol=ATOL, rtol=1e-3)


def test_eval_step_matches_train_forward(lenet, lenet_params):
    x, _ = make_batch(lenet, 4, seed=3)
    ev = M.make_eval_step(lenet)
    logits_eval = ev(lenet_params, x)
    logits_train, _ = lenet.forward(lenet_params, x, full_idxs(lenet), True)
    np.testing.assert_allclose(logits_eval, logits_train, atol=ATOL, rtol=1e-3)


# --------------------------------------------------- skeleton semantics


def test_identity_skeleton_equals_full_grad(lenet, lenet_params):
    """r=100% with identity indices must reproduce plain SGD exactly —
    this is why the r100 artifact doubles as the FedAvg baseline."""
    x, y = make_batch(lenet, 8, seed=4)
    step = M.make_train_step(lenet)
    new_s, loss_s, _ = step(
        lenet_params, lenet_params, x, y, full_idxs(lenet), jnp.float32(0.1), jnp.float32(0.0)
    )

    def ref_loss(ps):
        return M.softmax_cross_entropy(_lenet_ref_forward(ps, x), y)

    grads = jax.grad(ref_loss)(list(lenet_params))
    for ns, p, g in zip(new_s, lenet_params, grads):
        np.testing.assert_allclose(ns, p - 0.1 * g, atol=1e-3, rtol=1e-2)


def test_pruned_step_touches_only_skeleton(lenet, lenet_params):
    x, y = make_batch(lenet, 8, seed=5)
    step = M.make_train_step(lenet)
    idxs = [
        jnp.asarray([2], jnp.int32),
        jnp.asarray([1, 7, 9], jnp.int32),
        jnp.arange(12, dtype=jnp.int32),
        jnp.arange(8, dtype=jnp.int32),
    ]
    new, _, _ = step(lenet_params, lenet_params, x, y, idxs, jnp.float32(0.1), jnp.float32(0.0))
    # conv1 weight [5,5,1,6]: only output channel 2 may change.
    d1 = np.abs(np.asarray(new[0] - lenet_params[0])).reshape(-1, 6).sum(0)
    assert d1[2] > 0 and np.all(d1[[0, 1, 3, 4, 5]] == 0)
    # conv2 bias [16]: only {1,7,9}.
    d2 = np.abs(np.asarray(new[3] - lenet_params[3]))
    on = np.zeros(16, bool)
    on[[1, 7, 9]] = True
    assert np.all(d2[~on] == 0) and d2[on].sum() > 0
    # fc3 (head, never pruned) must still train.
    assert np.abs(np.asarray(new[8] - lenet_params[8])).sum() > 0


def test_pruned_grads_match_full_on_skeleton_channels(lenet, lenet_params):
    """The skeleton channels' update must equal the corresponding slice of
    the *last-layer-pruned* gradient only for the final prunable layer; for
    earlier layers upstream pruning changes dA. Check the invariant on fc2
    (deepest prunable layer, identical downstream path)."""
    x, y = make_batch(lenet, 8, seed=6)
    step = M.make_train_step(lenet)
    idx_fc2 = jnp.asarray([0, 5, 33], jnp.int32)
    idxs = [
        jnp.arange(6, dtype=jnp.int32),
        jnp.arange(16, dtype=jnp.int32),
        jnp.arange(120, dtype=jnp.int32),
        idx_fc2,
    ]
    new_pruned, _, _ = step(lenet_params, lenet_params, x, y, idxs, jnp.float32(0.1), jnp.float32(0.0))
    new_full, _, _ = step(
        lenet_params, lenet_params, x, y, full_idxs(lenet), jnp.float32(0.1), jnp.float32(0.0)
    )
    dw_pruned = np.asarray(new_pruned[6] - lenet_params[6])
    dw_full = np.asarray(new_full[6] - lenet_params[6])
    np.testing.assert_allclose(
        dw_pruned[:, [0, 5, 33]], dw_full[:, [0, 5, 33]], atol=1e-4, rtol=1e-3
    )


def test_prox_term_pulls_toward_global(lenet, lenet_params):
    """mu > 0 adds mu·(p − g) to the gradient (FedProx / FedMTL baseline)."""
    x, y = make_batch(lenet, 8, seed=7)
    step = M.make_train_step(lenet)
    gparams = [p + 1.0 for p in lenet_params]
    new0, _, _ = step(lenet_params, gparams, x, y, full_idxs(lenet), jnp.float32(0.1), jnp.float32(0.0))
    new1, _, _ = step(lenet_params, gparams, x, y, full_idxs(lenet), jnp.float32(0.1), jnp.float32(1.0))
    # With g = p + 1, prox gradient is −mu·1; update difference is +lr·mu.
    diff = np.asarray(new1[0] - new0[0])
    np.testing.assert_allclose(diff, 0.1 * np.ones_like(diff), atol=1e-4)


def test_importance_is_mean_abs_activation(lenet, lenet_params):
    """Eq. 2: M_i = mean |A_i| — check conv1's importance against a direct
    computation of its pooled activation."""
    x, y = make_batch(lenet, 8, seed=8)
    step = M.make_train_step(lenet)
    _, _, imps = step(lenet_params, lenet_params, x, y, full_idxs(lenet), jnp.float32(0.0), jnp.float32(0.0))

    z = jax.lax.conv_general_dilated(
        x, lenet_params[0], (1, 1), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC")
    ) + lenet_params[1][None, None, None, :]
    a1 = M.avg_pool2(jnp.maximum(z, 0))
    expect = jnp.mean(jnp.abs(a1), axis=(0, 1, 2))
    np.testing.assert_allclose(imps[0], expect, atol=1e-4, rtol=1e-3)


# ------------------------------------------------------------ convergence


def test_lenet_loss_decreases_under_pruned_training(lenet):
    """A few skeleton-pruned SGD steps on a separable toy problem must
    reduce the loss — gradient pruning may not break learning."""
    params = M.init_params(lenet, seed=9)
    rng = np.random.default_rng(10)
    # two-class problem: class = sign of mean pixel intensity bump
    n = 32
    x0 = rng.standard_normal((n, 28, 28, 1)).astype(np.float32)
    y = (np.arange(n) % 2).astype(np.int32)
    x0[y == 1, 8:20, 8:20, :] += 2.0
    x, y = jnp.asarray(x0), jnp.asarray(y)

    idxs = [
        jnp.asarray([0, 3], jnp.int32),           # conv1: 2/6
        jnp.asarray([1, 4, 7, 11], jnp.int32),    # conv2: 4/16
        jnp.arange(0, 120, 3, dtype=jnp.int32),   # fc1: 40/120
        jnp.arange(0, 84, 3, dtype=jnp.int32),    # fc2: 28/84
    ]
    step = jax.jit(M.make_train_step(lenet))
    losses = []
    for _ in range(12):
        params, loss, _ = step(params, params, x, y, idxs, jnp.float32(0.1), jnp.float32(0.0))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses


def test_resnet_train_step_runs_and_prunes():
    m = M.make_resnet(18, width=4)
    ps = M.init_params(m, 0)
    x, y = make_batch(m, 2, seed=11)
    idxs = [jnp.asarray([0], jnp.int32) for _ in m.prunable]
    step = M.make_train_step(m)
    new, loss, imps = step(ps, ps, x, y, idxs, jnp.float32(0.01), jnp.float32(0.0))
    assert np.isfinite(float(loss))
    assert len(imps) == len(m.prunable)
    # first block conv1 weight: only channel 0 column changes
    iw = m.prunable[0].weight_param
    d = np.abs(np.asarray(new[iw] - ps[iw])).reshape(-1, m.prunable[0].channels).sum(0)
    assert d[0] > 0 and np.all(d[1:] == 0)
