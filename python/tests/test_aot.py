"""AOT pipeline tests: manifest consistency and HLO-text well-formedness.

These guard the python→rust interchange contract: the rust runtime trusts
``manifest.json`` blindly, so every artifact's declared argument list must
match what the lowered HLO actually expects.
"""

import json
import math
import os
import re

import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def lenet():
    return M.make_lenet((28, 28, 1), 10, "lenet_smnist")


def entry_param_count(hlo_text: str) -> int:
    """Number of parameters of the ENTRY computation."""
    entry = hlo_text[hlo_text.index("ENTRY") :]
    return len(re.findall(r"= \S+ parameter\(\d+\)", entry))


def test_skel_sizes_ceil_and_floor(lenet):
    assert aot.skel_sizes(lenet, 100) == [6, 16, 120, 84]
    assert aot.skel_sizes(lenet, 10) == [1, 2, 12, 9]
    # never zero channels, even at absurd ratios
    assert aot.skel_sizes(lenet, 1) == [1, 1, 2, 1]


def test_lower_train_io_contract(lenet):
    text, spec = aot.lower_train(lenet, batch=4, ratio_pct=30)
    n_params = len(lenet.params)
    n_prun = len(lenet.prunable)
    assert len(spec["inputs"]) == 2 * n_params + 2 + n_prun + 2
    assert len(spec["outputs"]) == n_params + 1 + n_prun
    assert spec["k"] == [2, 5, 36, 26]
    # HLO text parses structurally: one ENTRY whose parameter count
    # matches the manifest contract (nested computations have their own
    # parameter(0..) numbering, so scope the count to ENTRY).
    assert "ENTRY" in text
    assert entry_param_count(text) == len(spec["inputs"])


def test_lower_eval_io_contract(lenet):
    text, spec = aot.lower_eval(lenet, batch=8)
    assert spec["outputs"][0]["shape"] == [8, 10]
    assert entry_param_count(text) == len(spec["inputs"])


def test_lower_convbwd_shapes(lenet):
    text, spec = aot.lower_convbwd(lenet, batch=16, ratio_pct=20)
    # lenet 28x28: conv1 GEMM M=16*24*24, conv2 GEMM M=16*8*8
    assert spec["gemms"] == [[16 * 576, 25, 6], [16 * 64, 150, 16]]
    assert spec["k"] == [2, 4]
    assert "ENTRY" in text


def test_manifest_on_disk_if_built():
    """If `make artifacts` has run, validate the real manifest."""
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    man = json.load(open(path))
    assert man["version"] == 1
    for mname, entry in man["models"].items():
        n_params = len(entry["params"])
        n_prun = len(entry["prunable"])
        assert entry["num_params"] == sum(
            math.prod(p["shape"]) for p in entry["params"]
        )
        for aname, art in entry["artifacts"].items():
            fpath = os.path.join(os.path.dirname(path), art["file"])
            assert os.path.exists(fpath), f"{mname}/{aname} missing file"
            if art["kind"] == "train":
                assert len(art["inputs"]) == 2 * n_params + 2 + n_prun + 2
                assert len(art["outputs"]) == n_params + 1 + n_prun
                for k, pr in zip(art["k"], entry["prunable"]):
                    assert 1 <= k <= pr["channels"]
            elif art["kind"] == "eval":
                assert art["outputs"][0]["shape"] == [
                    entry["eval_batch"],
                    entry["num_classes"],
                ]


def test_registry_names_match_model_names():
    reg = aot.model_registry(4)
    for name, build in reg.items():
        assert build().name == name
